"""Serve a BWQ-quantized model with batched greedy decoding (+ optional
int8 KV cache, the beyond-paper activation-side extension).

    PYTHONPATH=src python examples/serve_quantized.py
"""
import jax
import jax.numpy as jnp

from repro.configs import REGISTRY
from repro.models.api import build
from repro.models.common import QuantConfig
from repro.serve import ServeEngine

cfg = REGISTRY["phi3-mini-3.8b"].tiny(dtype="float32").with_quant(
    QuantConfig(mode="bitplane", n_bits=8, act_bits=8))
api = build(cfg)
params = api.init(jax.random.PRNGKey(0))

prompts = jnp.asarray(
    jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab),
    jnp.int32)

for kv_bits in (32, 8):
    eng = ServeEngine(api, params, kv_quant_bits=kv_bits)
    out = eng.generate({"tokens": prompts}, max_new=12)
    print(f"kv_quant={kv_bits:2d}-bit ->", out[0].tolist())
