"""Serve a BWQ-quantized model three ways:

* one-shot static-batch greedy decoding with a quantized-at-rest KV cache
  (int8 / nibble-packed int4 entries, written once, dequantized in-graph);
* request-level continuous batching — staggered arrivals stream through a
  fixed-capacity slot batch and still decode token-identically;
* deployed packed weights on the ``pallas`` execution backend — matmuls
  run on the compressed int8 representation (interpret mode on CPU) and
  emit the same greedy tokens as the dense dequant path.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import jax
import jax.numpy as jnp

from repro.configs import REGISTRY
from repro.models.api import build
from repro.models.common import QuantConfig
from repro.serve import Request, SamplingParams, ServeEngine
from repro.serve.deploy import to_serving_params

cfg = REGISTRY["phi3-mini-3.8b"].tiny(dtype="float32").with_quant(
    QuantConfig(mode="bitplane", n_bits=8, act_bits=8))
api = build(cfg)
params = api.init(jax.random.PRNGKey(0))

prompts = jnp.asarray(
    jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab),
    jnp.int32)

# one-shot batched decode at three KV-cache precisions
for kv_bits in (32, 8, 4):
    eng = ServeEngine(api, params, kv_quant_bits=kv_bits)
    out = eng.generate({"tokens": prompts}, max_new=12)
    print(f"kv_cache={kv_bits:2d}-bit ->", out[0].tolist())

# continuous batching: 4 requests arriving 2 ticks apart share 2 slots
eng = ServeEngine(api, params, kv_quant_bits=8)
requests = [
    Request(uid=i, inputs={"tokens": prompts[i:i + 1]},
            sampling=SamplingParams(max_new_tokens=12), arrival=2 * i)
    for i in range(4)
]
for r in eng.serve(requests, n_slots=2):
    print(f"req {r.uid}: admitted@{r.admitted_tick} done@{r.finished_tick} "
          f"({r.finish_reason}) {r.tokens}")

# deployed packed weights: dense dequant vs the Pallas packed kernel
packed = to_serving_params(params, bits=8)
for backend in ("dense", "pallas"):
    eng = ServeEngine(api, packed, kv_quant_bits=8, backend=backend)
    out = eng.generate({"tokens": prompts[:2]}, max_new=8)
    print(f"backend={backend:6s} ->", out[0].tolist())
