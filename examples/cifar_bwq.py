"""Paper-faithful CNN reproduction: ResNet (CIFAR-style) trained with
BWQ-A (9x8 WBs) vs BSQ (whole-layer blocks), then evaluated on the
ReRAM accelerator simulator — the paper's Table II + Fig 9 pipeline.

    PYTHONPATH=src python examples/cifar_bwq.py --steps 150
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import cnn_accuracy, train_quantized_cnn  # noqa
from repro.hw import (bwq_scheme, isaac_scheme, speedup_and_energy_saving,
                      workloads_from_params)
from repro.train.step import quant_stats

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=150)
args = ap.parse_args()

results = {}
for scheme in ("float", "bsq", "bwq"):
    qc, apply_fn, tr = train_quantized_cnn(scheme, steps=args.steps)
    acc = cnn_accuracy(apply_fn, tr.state.params, qc)
    st = quant_stats(tr.state.params)
    results[scheme] = (acc, float(st["compression_x"]), tr.state.params)
    print(f"{scheme:6s} acc={acc:.3f} compression={st['compression_x']:.1f}x")

wls = workloads_from_params(results["bwq"][2], positions=64, act_bits=3)
sp, en = speedup_and_energy_saving(wls, bwq_scheme(), isaac_scheme())
print(f"BWQ-H vs ISAAC on this model: {sp:.2f}x speedup, {en:.2f}x energy")
