"""End-to-end driver: train an LM with BWQ-A quantization-aware training.

Default is a CPU-friendly ~10M-param model for a few hundred steps; pass
--d-model 768 --layers 12 for a ~100M-param run (same code path, longer).

    PYTHONPATH=src python examples/train_bwq_lm.py --steps 200
"""
import argparse
import dataclasses

import jax

from repro.configs import REGISTRY
from repro.data import make_lm_pipeline
from repro.models.api import build
from repro.models.common import QuantConfig
from repro.optim import adamw, cosine_schedule
from repro.train import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--d-model", type=int, default=256)
ap.add_argument("--layers", type=int, default=4)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--mode", default="bitplane", choices=["bitplane", "fake"])
ap.add_argument("--ckpt-dir", default="/tmp/bwq_lm_ckpt")
args = ap.parse_args()

cfg = dataclasses.replace(
    REGISTRY["phi3-mini-3.8b"],
    n_layers=args.layers, d_model=args.d_model, n_heads=8, n_kv_heads=8,
    d_head=args.d_model // 8, d_ff=4 * args.d_model, vocab=8192,
    remat=False, dtype="float32",
    quant=QuantConfig(mode=args.mode, n_bits=8, act_bits=8,
                      wb_rows=9, wb_cols=8))
api = build(cfg)
params = api.init(jax.random.PRNGKey(0))
n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
print(f"model tensors hold {n_params/1e6:.1f}M scalars "
      f"({args.mode} QAT representation)")

trainer = Trainer(
    lambda p, b: api.loss(p, b), adamw(weight_decay=0.0),
    cosine_schedule(2e-3, args.steps), params,
    TrainerConfig(total_steps=args.steps, ckpt_every=max(args.steps // 3, 1),
                  ckpt_dir=args.ckpt_dir, log_every=max(args.steps // 10, 1),
                  requant_interval=max(args.steps // 6, 1),
                  alpha_round_steps=max(args.steps // 6, 1),
                  delta_alpha=1e-3))
resumed = trainer.try_restore()
if resumed:
    print(f"resumed from checkpoint at step {resumed}")
data = make_lm_pipeline(cfg, seq_len=args.seq, batch=args.batch,
                        start_step=resumed)
trainer.run(data, steps=args.steps)
for h in trainer.history:
    print(f"step {h['step']:5d}  ce={h['ce']:.4f}  "
          f"avg_bits={h['avg_bitwidth']:.2f}  comp={h['compression_x']:.1f}x")
