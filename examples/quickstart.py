"""Quickstart: quantize a weight matrix with BWQ-A primitives, inspect the
learned structures, and run the hardware simulator on it.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BlockingSpec, adjust_precision, bitwidths, compose,
                        from_float, requantize, wb_group_lasso)
from repro.hw import bwq_scheme, isaac_scheme, simulate, workload_from_qt

# 1. a weight matrix, partitioned into OU-sized (9x8) weight blocks
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (90, 80)) * 0.1
qt = from_float(w, n_bits=8, spec=BlockingSpec(9, 8))
print("blocks:", qt.mask.shape[1:], "| recon err:",
      float(jnp.max(jnp.abs(compose(qt) - w))))

# 2. sparsify some planes (in training, the WB-level group Lasso does this),
#    re-quantize and run the paper's MSB-down precision adjustment
planes = qt.planes.at[4:, :45, :].set(0.0)     # top rows become low-precision
qt = requantize(adjust_precision(dataclasses.replace(qt, planes=planes)))
bw = np.asarray(bitwidths(qt))
print("per-WB bit-widths:\n", bw.astype(int))
print("group lasso:", float(wb_group_lasso(qt)))

# 3. estimate ReRAM-accelerator speedup/energy for this mixed-precision state
wl = workload_from_qt("layer0", qt, positions=64, act_bits=3)
rep_bwq = simulate([wl], bwq_scheme())
rep_isaac = simulate([wl], isaac_scheme())
print(f"BWQ-H vs ISAAC: {rep_isaac.latency_s / rep_bwq.latency_s:.2f}x "
      f"speedup, {rep_isaac.energy_j / rep_bwq.energy_j:.2f}x energy saving")
