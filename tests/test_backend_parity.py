"""Kernel-vs-dense execution backend parity.

* every model family forward (dense / MoE / enc-dec / CNN) on deployed
  packed weights under ``backend="pallas"`` (interpret mode on CPU) and
  ``backend="ref"`` matches ``backend="dense"`` within fp32 tolerance —
  including int4 with the paper's 9x8 WB geometry, whose block padding
  produces an odd K (one zero nibble row);
* stacked (scanned) weights: a layer slice of a stacked ServingWeight
  executes identically through the kernel;
* the decoder-only ServeEngine is token-identical across backends under
  greedy decode (the PR acceptance criterion);
* ep_mode sharded MoE honors ``GROUPED_IMPL["impl"] == "ragged"`` (exact,
  no capacity drops) — 2-device subprocess vs the single-device oracle.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.kernels import default_interpret
from repro.models.api import build
from repro.models.common import (QuantConfig, make_weight, matmul_backend,
                                 qmatmul)
from repro.serve import ServeEngine
from repro.serve.deploy import to_serving_params

KEY = jax.random.PRNGKey(7)


def _setup(arch, bits):
    cfg = REGISTRY[arch].tiny(dtype="float32").with_quant(
        QuantConfig(mode="fake", n_bits=8, act_bits=8))   # 9x8 WB geometry
    api = build(cfg)
    params = to_serving_params(api.init(jax.random.PRNGKey(0)), bits)
    return cfg, api, params


def _batch(cfg, b=2, p=8):
    batch = {"tokens": jax.random.randint(
        KEY, (b, p), 0, cfg.vocab).astype(jnp.int32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.fold_in(KEY, 1),
            (b, cfg.vision_tokens, cfg.d_model)) * 0.1
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(KEY, 1), (b, p, cfg.d_model)) * 0.1
    return batch


def test_interpret_autodetects_off_tpu():
    assert default_interpret() == (jax.default_backend() != "tpu")


# ---------------------------------------------------------------------------
# forward-logit parity per family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "granite-moe-3b-a800m",
                                  "seamless-m4t-large-v2"])
@pytest.mark.parametrize("bits", [8, 4])
def test_family_forward_parity(arch, bits):
    """Prefill logits agree across backends on int8 AND int4 packing
    (int4 under the default 9x8 spec exercises odd block-padded K)."""
    cfg, api, params = _setup(arch, bits)
    batch = _batch(cfg)
    ref, _ = ServeEngine(api, params, backend="dense").prefill(batch)
    for be in ("pallas", "ref"):
        got, _ = ServeEngine(api, params, backend=be).prefill(batch)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"{arch} int{bits} {be}")


@pytest.mark.parametrize("bits", [8, 4])
def test_cnn_forward_parity(bits):
    """ResNet im2col path: packed conv weights through the kernel match
    the dense dequant path."""
    from repro.models.cnn import resnet_apply, resnet_init
    qc = QuantConfig(mode="fake", n_bits=8)              # 9x8 blocks
    params = resnet_init(jax.random.PRNGKey(0), qc, depth=8)
    sp = to_serving_params(params, bits)
    x = jax.random.normal(KEY, (2, 8, 8, 3))
    with matmul_backend("dense"):
        ref = np.asarray(resnet_apply(sp, x, qc))
    for be in ("pallas", "ref"):
        with matmul_backend(be):
            got = np.asarray(resnet_apply(sp, x, qc))
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4,
                                   err_msg=f"cnn int{bits} {be}")


def test_bitplane_matmul_ragged_n_pads_and_trims():
    """N not a multiple of wbc must pad-and-trim, not return uninitialized
    memory (regression: a zero-size grid dimension silently yielded NaN)."""
    from repro.core import BlockingSpec, from_float, requantize
    from repro.kernels import bitplane_matmul, to_bitplane_layout
    from repro.kernels.ref import bitplane_matmul_ref
    qt = requantize(from_float(
        jax.random.normal(KEY, (256, 128)) * 0.05, 8, BlockingSpec(8, 128)))
    bl = to_bitplane_layout(qt)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (4, 256))
    n = 100                                              # ragged slice
    y = bitplane_matmul(x, bl.planes_packed[:, :, :n], bl.sign_packed[:, :n],
                        bl.mask, bl.scale)
    y_ref = bitplane_matmul_ref(x, bl.planes_packed, bl.sign_packed,
                                bl.mask, bl.scale[0])[:, :n]
    assert np.isfinite(np.asarray(y)).all()
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_stacked_scanned_weight_slice():
    """A layer slice of a stacked (L, K, N) ServingWeight — what the layer
    scan feeds qmatmul — runs identically through the packed kernel.
    K=63 with 9x8 blocks pads to an odd Kp=63, hitting the int4 odd-K
    packing."""
    qc = QuantConfig(mode="fake", n_bits=8)
    w = make_weight(jax.random.PRNGKey(2), (3, 63, 32), qc)
    x = jax.random.normal(KEY, (4, 5, 63))               # (B, S, K)
    for bits in (8, 4):
        sw = to_serving_params({"w": w}, bits)["w"]
        sw1 = jax.tree_util.tree_map(lambda a: a[1], sw)  # scan slice
        y_ref = qmatmul(x, sw1, backend="dense")
        for be in ("pallas", "ref"):
            y = qmatmul(x, sw1, backend=be)
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       rtol=2e-5, atol=2e-5,
                                       err_msg=f"int{bits} {be}")


# ---------------------------------------------------------------------------
# token-identical engine decode (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4])
def test_engine_greedy_decode_token_identical(bits):
    cfg, api, params = _setup("phi3-mini-3.8b", bits)
    batch = _batch(cfg, b=3, p=8)
    out = {be: np.asarray(
        ServeEngine(api, params, kv_quant_bits=8, backend=be)
        .generate(batch, max_new=6)) for be in ("dense", "pallas", "ref")}
    np.testing.assert_array_equal(out["dense"], out["pallas"])
    np.testing.assert_array_equal(out["dense"], out["ref"])


def test_backend_validation_and_warning():
    cfg, api, params = _setup("phi3-mini-3.8b", 8)
    with pytest.raises(ValueError):
        ServeEngine(api, params, backend="tpuv7")
    qat = api.init(jax.random.PRNGKey(0))               # no packed leaves
    with pytest.warns(UserWarning, match="packed"):
        ServeEngine(api, qat, backend="pallas")


# ---------------------------------------------------------------------------
# ep_mode honors the exact 'ragged' dispatch (2 devices, subprocess)
# ---------------------------------------------------------------------------

_EP_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import REGISTRY
from repro.models.api import build
from repro.models.common import QuantConfig
from repro.models import moe as moe_mod
from repro.dist.sharding import use_mesh
from repro.launch.mesh import make_mesh

assert jax.device_count() == 2, jax.device_count()
assert moe_mod.GROUPED_IMPL["impl"] == "ragged"
cfg = REGISTRY["granite-moe-3b-a800m"].tiny(dtype="float32").with_quant(
    QuantConfig(mode="fake", n_bits=8, act_bits=8))
api = build(cfg)
params = api.init(jax.random.PRNGKey(0))
# skewed routing comes free from a random init; batch >> capacity*mean
batch = {"tokens": jax.random.randint(
    jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab).astype(jnp.int32),
    "labels": jnp.zeros((4, 16), jnp.int32)}
ref, _ = api.loss(params, batch)
with use_mesh(make_mesh((1, 2), ("data", "model"))):
    got, _ = api.loss(params, batch)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=1e-5, atol=1e-5)
print("EP_RAGGED_OK")
"""


def test_ep_mode_ragged_exact_two_devices():
    """Sharded ep_mode MoE with the exact 'ragged' impl must match the
    single-device no-drop path bit-for-bit-ish even under skewed routing
    (regression: it silently used capacity-dropping dispatch)."""
    env = dict(os.environ,
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")] +
                   sys.path))
    out = subprocess.run([sys.executable, "-c", _EP_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "EP_RAGGED_OK" in out.stdout
