"""Kernel-vs-dense execution backend parity, across BOTH wire formats.

* every model family forward (dense / MoE / enc-dec / CNN) on deployed
  packed weights under ``backend="pallas"`` (interpret mode on CPU) and
  ``backend="ref"`` matches ``backend="dense"`` within fp32 tolerance —
  including int4 with the paper's 9x8 WB geometry, whose block padding
  produces an odd K (one zero nibble row);
* the bit-plane serving layout composes the *bit-identical* weight as the
  packed layout (same integer grid, same per-WB effective scale), so the
  parity matrix extends across representations, not just kernels;
* stacked (scanned) weights: a layer slice of a stacked ServingWeight
  executes identically through the kernel;
* the ServeEngine is token-identical across the FULL backend matrix
  (dense / pallas / ref on packed, bitplane on plane-sliced) under greedy
  decode for transformer, MoE and enc-dec families at int8 AND int4 (the
  PR acceptance criterion);
* ``weight_stream_bytes`` counts per-block plane occupancy for the
  bit-plane layout (pinned byte counts for a known mixed assignment);
* ep_mode sharded MoE honors ``GROUPED_IMPL["impl"] == "ragged"`` (exact,
  no capacity drops) — 2-device subprocess vs the single-device oracle.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.kernels import default_interpret
from repro.models.api import build
from repro.models.common import (QuantConfig, make_weight, matmul_backend,
                                 qmatmul)
from repro.serve import ServeEngine
from repro.serve.deploy import (bitplane_stream_bytes, to_serving_params,
                                weight_stream_bytes)

KEY = jax.random.PRNGKey(7)

FAMILIES = ["phi3-mini-3.8b", "granite-moe-3b-a800m", "seamless-m4t-large-v2"]


def _setup(arch, bits, layout="packed"):
    cfg = REGISTRY[arch].tiny(dtype="float32").with_quant(
        QuantConfig(mode="fake", n_bits=8, act_bits=8))   # 9x8 WB geometry
    api = build(cfg)
    params = to_serving_params(api.init(jax.random.PRNGKey(0)), bits,
                               layout=layout)
    return cfg, api, params


def _batch(cfg, b=2, p=8):
    batch = {"tokens": jax.random.randint(
        KEY, (b, p), 0, cfg.vocab).astype(jnp.int32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.fold_in(KEY, 1),
            (b, cfg.vision_tokens, cfg.d_model)) * 0.1
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(KEY, 1), (b, p, cfg.d_model)) * 0.1
    return batch


def test_interpret_autodetects_off_tpu():
    assert default_interpret() == (jax.default_backend() != "tpu")


# ---------------------------------------------------------------------------
# forward-logit parity per family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", FAMILIES)
@pytest.mark.parametrize("bits", [8, 4])
def test_family_forward_parity(arch, bits):
    """Prefill logits agree across backends AND wire formats on int8 and
    int4 packing (int4 under the default 9x8 spec exercises odd
    block-padded K).  The dense compose of the bit-plane layout must be
    *bit-identical* to the packed layout — same integer grid."""
    cfg, api, params = _setup(arch, bits)
    _, _, bp = _setup(arch, bits, layout="bitplane")
    batch = _batch(cfg)
    ref, _ = ServeEngine(api, params, backend="dense").prefill(batch)
    ref_bp, _ = ServeEngine(api, bp, backend="dense").prefill(batch)
    np.testing.assert_allclose(np.asarray(ref_bp), np.asarray(ref),
                               rtol=1e-6, atol=1e-6,
                               err_msg=f"{arch} int{bits} cross-layout")
    for be, p in (("pallas", params), ("ref", params),
                  ("bitplane", bp), ("ref", bp)):
        got, _ = ServeEngine(api, p, backend=be).prefill(batch)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"{arch} int{bits} {be}")


@pytest.mark.parametrize("bits", [8, 4])
def test_cnn_forward_parity(bits):
    """ResNet im2col path: packed conv weights through the kernel match
    the dense dequant path."""
    from repro.models.cnn import resnet_apply, resnet_init
    qc = QuantConfig(mode="fake", n_bits=8)              # 9x8 blocks
    params = resnet_init(jax.random.PRNGKey(0), qc, depth=8)
    sp = to_serving_params(params, bits)
    bp = to_serving_params(params, bits, layout="bitplane")
    x = jax.random.normal(KEY, (2, 8, 8, 3))
    with matmul_backend("dense"):
        ref = np.asarray(resnet_apply(sp, x, qc))
    for be, p in (("pallas", sp), ("ref", sp), ("bitplane", bp)):
        with matmul_backend(be):
            got = np.asarray(resnet_apply(p, x, qc))
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4,
                                   err_msg=f"cnn int{bits} {be}")


def test_bitplane_matmul_ragged_n_pads_and_trims():
    """N not a multiple of wbc must pad-and-trim, not return uninitialized
    memory (regression: a zero-size grid dimension silently yielded NaN)."""
    from repro.core import BlockingSpec, from_float, requantize
    from repro.kernels import bitplane_matmul, to_bitplane_layout
    from repro.kernels.ref import bitplane_matmul_ref
    qt = requantize(from_float(
        jax.random.normal(KEY, (256, 128)) * 0.05, 8, BlockingSpec(8, 128)))
    bl = to_bitplane_layout(qt)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (4, 256))
    n = 100                                              # ragged slice
    y = bitplane_matmul(x, bl.planes_packed[:, :, :n], bl.sign_packed[:, :n],
                        bl.mask, bl.scale)
    y_ref = bitplane_matmul_ref(x, bl.planes_packed, bl.sign_packed,
                                bl.mask, bl.scale[0])[:, :n]
    assert np.isfinite(np.asarray(y)).all()
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_stacked_scanned_weight_slice():
    """A layer slice of a stacked (L, K, N) ServingWeight — what the layer
    scan feeds qmatmul — runs identically through the packed kernel.
    K=63 with 9x8 blocks pads to an odd Kp=63, hitting the int4 odd-K
    packing."""
    qc = QuantConfig(mode="fake", n_bits=8)
    w = make_weight(jax.random.PRNGKey(2), (3, 63, 32), qc)
    x = jax.random.normal(KEY, (4, 5, 63))               # (B, S, K)
    for bits in (8, 4):
        sw = to_serving_params({"w": w}, bits)["w"]
        sw1 = jax.tree_util.tree_map(lambda a: a[1], sw)  # scan slice
        y_ref = qmatmul(x, sw1, backend="dense")
        for be in ("pallas", "ref"):
            y = qmatmul(x, sw1, backend=be)
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       rtol=2e-5, atol=2e-5,
                                       err_msg=f"int{bits} {be}")
        # same contract for the bit-plane layout: layer-stack dims lead,
        # so a scan slice is exactly the kernel-facing (bits, K8, N) form
        bw = to_serving_params({"w": w}, bits, layout="bitplane")["w"]
        bw1 = jax.tree_util.tree_map(lambda a: a[1], bw)
        for be in ("bitplane", "ref"):
            y = qmatmul(x, bw1, backend=be)
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       rtol=2e-5, atol=2e-5,
                                       err_msg=f"bitplane int{bits} {be}")


# ---------------------------------------------------------------------------
# token-identical engine decode over the full backend matrix (acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", FAMILIES)
@pytest.mark.parametrize("bits", [8, 4])
def test_engine_greedy_decode_token_identical_matrix(arch, bits):
    """Greedy decodes are token-identical across the full backend matrix
    — dense / pallas / ref on the packed layout, bitplane on the
    plane-sliced layout — for transformer, MoE and enc-dec families at
    int8 and int4."""
    cfg, api, params = _setup(arch, bits)
    _, _, bp = _setup(arch, bits, layout="bitplane")
    batch = _batch(cfg, b=2, p=8)
    out = {}
    for be, p in (("dense", params), ("pallas", params), ("ref", params),
                  ("bitplane", bp)):
        out[be] = np.asarray(
            ServeEngine(api, p, kv_quant_bits=8, backend=be)
            .generate(batch, max_new=4))
    for be in ("pallas", "ref", "bitplane"):
        np.testing.assert_array_equal(out[be], out["dense"],
                                      err_msg=f"{arch} int{bits} {be}")


def test_backend_validation_and_warning():
    cfg, api, params = _setup("phi3-mini-3.8b", 8)
    with pytest.raises(ValueError):
        ServeEngine(api, params, backend="tpuv7")
    qat = api.init(jax.random.PRNGKey(0))               # no packed leaves
    with pytest.warns(UserWarning, match="packed"):
        ServeEngine(api, qat, backend="pallas")
    # bitplane accelerates only the plane-sliced layout: a packed tree
    # must warn (execution would silently fall back to dense)
    with pytest.warns(UserWarning, match="bitplane"):
        ServeEngine(api, params, backend="bitplane")


# ---------------------------------------------------------------------------
# weight_stream_bytes: per-block plane occupancy
# ---------------------------------------------------------------------------

def test_weight_stream_bytes_bitplane_occupancy():
    """Pinned byte counts for a known mixed-precision assignment under the
    paper's 9x8 geometry: (K, N) = (18, 16) -> 2x2 WB grid with live
    bit-widths [[2, 4], [0, 8]].

    Per live (bit, block) entry one 72-bit plane tile streams; blocks
    with any live plane also stream their 72-bit sign tile; the mask LUT
    is 1 bit/entry and the scale LUT stored f32."""
    qc = QuantConfig(mode="fake", n_bits=8)              # 9x8 blocks
    fq = make_weight(jax.random.PRNGKey(0), (18, 16), qc)
    fq = dataclasses.replace(
        fq, bitwidth=jnp.asarray([[2., 4.], [0., 8.]]))
    bp8 = to_serving_params({"w": fq}, 8, layout="bitplane")["w"]
    bp4 = to_serving_params({"w": fq}, 4, layout="bitplane")["w"]
    # int8 container: live planes min(bw, 8) = 2+4+0+8 = 14, live blocks 3
    #   -> ceil((14+3)*72 / 8) + ceil(8*4 / 8) + 4*4 = 153 + 4 + 16 = 173
    assert bitplane_stream_bytes(bp8) == 173
    # int4 container: live planes min(bw, 4) = 2+4+0+4 = 10
    #   -> (10+3)*72/8 + ceil(4*4 / 8) + 4*4 = 117 + 2 + 16 = 135
    assert bitplane_stream_bytes(bp4) == 135
    assert weight_stream_bytes({"w": bp8}) == 173
    # the mask LUT mirrors the assignment (plane b live iff b < bw)
    mask = np.asarray(bp8.mask)                          # (8, 2, 2)
    np.testing.assert_array_equal(mask.sum(axis=0), [[2, 4], [0, 8]])
    # pruning planes strictly reduces streamed bytes vs the uniform tree
    uniform = to_serving_params(
        {"w": make_weight(jax.random.PRNGKey(0), (18, 16), qc)}, 8,
        layout="bitplane")["w"]
    assert bitplane_stream_bytes(bp8) < bitplane_stream_bytes(uniform)


def test_weight_stream_bytes_bitplane_below_dense():
    """Acceptance: any deploy-bits < 8 bit-plane assignment streams
    strictly fewer bytes per step than the dense (QAT float) tree — and
    int4 fewer than int8 (4 planes + sign vs 8 planes + sign)."""
    _, api, _ = _setup("phi3-mini-3.8b", 8)
    qat = api.init(jax.random.PRNGKey(0))
    dense_bytes = weight_stream_bytes(qat)
    bp8 = weight_stream_bytes(to_serving_params(qat, 8, layout="bitplane"))
    bp4 = weight_stream_bytes(to_serving_params(qat, 4, layout="bitplane"))
    assert bp4 < bp8 < dense_bytes


# ---------------------------------------------------------------------------
# ep_mode honors the exact 'ragged' dispatch (2 devices, subprocess)
# ---------------------------------------------------------------------------

_EP_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import REGISTRY
from repro.models.api import build
from repro.models.common import QuantConfig
from repro.models import moe as moe_mod
from repro.dist.sharding import use_mesh
from repro.launch.mesh import make_mesh

assert jax.device_count() == 2, jax.device_count()
assert moe_mod.GROUPED_IMPL["impl"] == "ragged"
cfg = REGISTRY["granite-moe-3b-a800m"].tiny(dtype="float32").with_quant(
    QuantConfig(mode="fake", n_bits=8, act_bits=8))
api = build(cfg)
params = api.init(jax.random.PRNGKey(0))
# skewed routing comes free from a random init; batch >> capacity*mean
batch = {"tokens": jax.random.randint(
    jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab).astype(jnp.int32),
    "labels": jnp.zeros((4, 16), jnp.int32)}
ref, _ = api.loss(params, batch)
with use_mesh(make_mesh((1, 2), ("data", "model"))):
    got, _ = api.loss(params, batch)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=1e-5, atol=1e-5)
print("EP_RAGGED_OK")
"""


def test_ep_mode_ragged_exact_two_devices():
    """Sharded ep_mode MoE with the exact 'ragged' impl must match the
    single-device no-drop path bit-for-bit-ish even under skewed routing
    (regression: it silently used capacity-dropping dispatch)."""
    env = dict(os.environ,
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")] +
                   sys.path))
    out = subprocess.run([sys.executable, "-c", _EP_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "EP_RAGGED_OK" in out.stdout
