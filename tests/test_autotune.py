"""Precision autotuner + self-speculative decoding (serve.autotune).

Covers the PR's acceptance criteria:

* greedy budget search: allocations respect ``weight_stream_bytes``
  budgets exactly (AT1), stay BP1-BP3-valid, and round-trip
  bit-identically when the budget admits every plane;
* budget monotonicity as a randomized property: a larger budget never
  yields a higher predicted error;
* emitted LUTs pass the serving contracts for random 9x8-geometry
  shapes (the paper's OU tile), not just the model fixtures;
* draft trees: ``truncate_mask_topk`` keeps exactly the top-k live
  planes and ``validate_draft_truncation`` (AT2) accepts them;
* speculative decode: greedy output is token-identical to the
  non-speculative engine across families x deploy bits x cache layouts,
  and a paged run drains leak-free;
* the bitplane dense-fallback lint is an ERROR under preflight while
  engine construction still only warns.

Property sweeps run under `hypothesis` when installed, else the seeded
fallback driver (`repro.testing.proptest`).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                        # optional dep: seeded fallback
    from repro.testing import proptest as _pt
    given, settings, st = _pt.given, _pt.settings, _pt

from repro.analysis import lint_engine
from repro.analysis.contracts import (validate_allocation,
                                      validate_draft_truncation,
                                      validate_serving_tree)
from repro.configs import REGISTRY
from repro.core import BlockingSpec, from_float
from repro.kernels.ops import truncate_mask_topk
from repro.models.api import build
from repro.models.common import QuantConfig
from repro.serve import Request, SamplingParams, ServeEngine
from repro.serve.autotune import (autotune_params, calibrate_activations,
                                  greedy_allocate, make_draft_params,
                                  sensitivity_tree)
from repro.serve.deploy import (BitplaneServingWeight, to_serving_params,
                                weight_stream_bytes)

SETTINGS = dict(max_examples=12, deadline=None)


@functools.lru_cache(maxsize=None)
def _deployed(arch: str, bits: int = 8):
    cfg = REGISTRY[arch].tiny(dtype="float32").with_quant(
        QuantConfig(mode="fake", n_bits=8, act_bits=8))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return api, to_serving_params(params, bits, layout="bitplane")


def _batch(cfg, b=2, t=8, seed=1):
    return {"tokens": jax.random.randint(
        jax.random.PRNGKey(seed), (b, t), 0, cfg.vocab).astype(jnp.int32)}


def _bp_leaves(tree):
    return [l for l in jax.tree_util.tree_leaves(
        tree, is_leaf=lambda x: isinstance(x, BitplaneServingWeight))
        if isinstance(l, BitplaneServingWeight)]


@functools.lru_cache(maxsize=None)
def _toy_tree(k: int, n: int, n_bits: int, seed: int):
    """A single random bitplane serving leaf on the paper's 9x8 tile."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n))
    qt = from_float(w, n_bits, BlockingSpec(9, 8))
    return to_serving_params({"w": qt}, n_bits, layout="bitplane")


# ---------------------------------------------------------------- mask topk

def test_truncate_mask_topk_keeps_highest_live_planes():
    # occupancies 3 and 1 out of 4 planes
    mask = jnp.array([[[1.0, 1.0]], [[1.0, 0.0]], [[1.0, 0.0]],
                      [[0.0, 0.0]]])
    out = np.asarray(truncate_mask_topk(mask, 2))
    # occ=3 column keeps planes {1,2}; occ=1 column keeps plane {0}
    want = np.array([[[0.0, 1.0]], [[1.0, 0.0]], [[1.0, 0.0]],
                     [[0.0, 0.0]]])
    np.testing.assert_array_equal(out, want)


def test_truncate_mask_topk_k_at_least_occ_is_identity():
    mask = jnp.array([[[1.0]], [[1.0]], [[0.0]]])
    np.testing.assert_array_equal(np.asarray(truncate_mask_topk(mask, 5)),
                                  np.asarray(mask))
    with pytest.raises(ValueError):
        truncate_mask_topk(mask, -1)


def test_draft_tree_passes_at2():
    api, sp = _deployed("phi3-mini-3.8b")
    for k in (1, 2, 7, 12):
        draft = make_draft_params(sp, k)
        findings = validate_draft_truncation(draft, sp)
        assert not [f for f in findings if f.severity == "error"], \
            [f.format() for f in findings]
    # payloads are shared views, only the mask differs
    d, f = _bp_leaves(make_draft_params(sp, 2)), _bp_leaves(sp)
    assert all(a.planes is b.planes and a.scale is b.scale
               for a, b in zip(d, f))


# ------------------------------------------------------------- budget search

def test_full_budget_allocation_is_bit_identical():
    api, sp = _deployed("phi3-mini-3.8b")
    full = weight_stream_bytes(sp)
    alloc = greedy_allocate(sp, sensitivity_tree(sp), full)
    assert alloc.total_bytes == full
    assert alloc.steps_taken == alloc.steps_available
    for a, b in zip(_bp_leaves(sp), _bp_leaves(alloc.params)):
        np.testing.assert_array_equal(np.asarray(a.planes),
                                      np.asarray(b.planes))
        np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))
        np.testing.assert_allclose(np.asarray(a.scale), np.asarray(b.scale))


def test_allocation_respects_budget_exactly():
    api, sp = _deployed("phi3-mini-3.8b")
    full = weight_stream_bytes(sp)
    for frac in (0.6, 0.8, 0.95):
        budget = int(full * frac)
        alloc = greedy_allocate(sp, sensitivity_tree(sp), budget)
        assert alloc.total_bytes <= budget
        assert alloc.total_bytes == weight_stream_bytes(alloc.params)
        assert not validate_allocation(alloc.params, budget)      # AT1
        assert not [f for f in validate_serving_tree(alloc.params)
                    if f.severity == "error"]                     # BP1-BP3


def test_infeasible_budget_raises():
    api, sp = _deployed("phi3-mini-3.8b")
    with pytest.raises(ValueError):
        greedy_allocate(sp, sensitivity_tree(sp), 16)


def test_calibrated_autotune_with_quality_gate():
    api, sp = _deployed("phi3-mini-3.8b")
    batch = _batch(api.cfg)
    act2 = calibrate_activations(api, sp, batch)
    assert act2 and all(v is not None for v in act2.values())
    full = weight_stream_bytes(sp)
    alloc = autotune_params(api, sp, full, batch=batch,
                            min_top1_agreement=1.0, require_gate=True)
    # full budget keeps every plane: the gate must report exact agreement
    assert alloc.gate["ok"] and alloc.gate["top1_agreement"] == 1.0
    assert alloc.gate["max_abs_logit_diff"] == 0.0


@given(st.integers(10, 60), st.integers(8, 48), st.sampled_from([4, 8]),
       st.integers(0, 2 ** 16), st.floats(0.55, 1.0))
@settings(**SETTINGS)
def test_random_geometry_allocations_pass_bp2(k, n, n_bits, seed, frac):
    """Emitted LUTs satisfy the serving contracts (incl. BP2 prefix
    monotonicity) for random shapes on the 9x8 weight-block tile."""
    sp = _toy_tree(k, n, n_bits, seed)
    full = weight_stream_bytes(sp)
    alloc = greedy_allocate(sp, sensitivity_tree(sp), int(full * frac))
    assert alloc.total_bytes <= int(full * frac)
    assert not [f for f in validate_serving_tree(alloc.params)
                if f.severity == "error"]
    assert not validate_allocation(alloc.params, int(full * frac))


@given(st.integers(10, 60), st.integers(8, 48), st.integers(0, 2 ** 16),
       st.floats(0.5, 0.9), st.floats(0.02, 0.3))
@settings(**SETTINGS)
def test_larger_budget_never_predicts_higher_error(k, n, seed, frac, bump):
    sp = _toy_tree(k, n, 8, seed)
    scores = sensitivity_tree(sp)
    full = weight_stream_bytes(sp)
    lo = greedy_allocate(sp, scores, int(full * frac))
    hi = greedy_allocate(sp, scores, int(full * min(frac + bump, 1.0)))
    assert hi.predicted_error <= lo.predicted_error + 1e-9
    assert hi.total_bytes >= lo.total_bytes


# ------------------------------------------------------- speculative decode

def test_speculative_generate_token_identical():
    api, sp = _deployed("phi3-mini-3.8b")
    batch = _batch(api.cfg)
    ref = np.asarray(ServeEngine(api, sp, backend="bitplane")
                     .generate(batch, max_new=10))
    for k, gamma in ((2, 3), (6, 4)):
        eng = ServeEngine(api, sp, backend="bitplane",
                          speculate_planes=k, draft_gamma=gamma)
        out = np.asarray(eng.generate(batch, max_new=10))
        np.testing.assert_array_equal(out, ref)


def _sched_tokens(engine, cfg, page_size=0):
    reqs = [Request(uid=i,
                    inputs={"tokens": jax.random.randint(
                        jax.random.PRNGKey(10 + i), (1, 5 + i), 0,
                        cfg.vocab).astype(jnp.int32)},
                    sampling=SamplingParams(max_new_tokens=9,
                                            temperature=0.0),
                    arrival=i * 2)
            for i in range(3)]
    sched = engine.make_scheduler(reqs, n_slots=2, page_size=page_size)
    return {r.uid: r.tokens for r in sched.run(reqs)}, sched


def test_speculative_scheduler_paged_parity_and_leak_free():
    api, sp = _deployed("phi3-mini-3.8b")
    ref, _ = _sched_tokens(ServeEngine(api, sp, backend="bitplane"),
                           api.cfg, page_size=8)
    eng = ServeEngine(api, sp, backend="bitplane", speculate_planes=6,
                      draft_gamma=3)
    out, sched = _sched_tokens(eng, api.cfg, page_size=8)
    assert out == ref
    assert sched.spec_stats["rounds"] > 0
    assert sched.spec_stats["drafted"] >= sched.spec_stats["accepted_drafts"]
    rep = sched.cache_report()
    assert rep["pages_in_use"] == 0                       # leak-free drain
    assert sched.allocator.reserved == 0
    assert np.all(sched.tables == 0)       # every table back on trash page


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "granite-moe-3b-a800m"])
@pytest.mark.parametrize("bits", [8, 4])
def test_speculative_parity_matrix(arch, bits):
    api, sp = _deployed(arch, bits)
    batch = _batch(api.cfg)
    ref = np.asarray(ServeEngine(api, sp, backend="bitplane")
                     .generate(batch, max_new=10))
    eng = ServeEngine(api, sp, backend="bitplane",
                      speculate_planes=bits - 1, draft_gamma=4)
    np.testing.assert_array_equal(
        np.asarray(eng.generate(batch, max_new=10)), ref)
    sref, _ = _sched_tokens(ServeEngine(api, sp, backend="bitplane"),
                            api.cfg)
    sout, _ = _sched_tokens(eng, api.cfg)
    assert sout == sref


def test_speculative_engine_guards():
    api, sp = _deployed("phi3-mini-3.8b")
    with pytest.raises(ValueError):
        ServeEngine(api, sp, backend="bitplane", speculate_planes=2,
                    draft_gamma=0)
    cfg = REGISTRY["zamba2-1.2b"].tiny(dtype="float32").with_quant(
        QuantConfig(mode="fake", n_bits=8, act_bits=8))
    hapi = build(cfg)
    hp = to_serving_params(hapi.init(jax.random.PRNGKey(0)), 8,
                           layout="bitplane")
    with pytest.raises(ValueError):
        ServeEngine(hapi, hp, backend="bitplane", speculate_planes=2)
    with pytest.raises(ValueError):
        make_draft_params({"w": jnp.ones((4, 4))}, 2)  # no bitplane leaves


# ------------------------------------------------------------ lint severity

def test_lint_engine_errors_on_bitplane_dense_fallback():
    """Preflight (satellite of this PR): a bitplane engine that would
    silently dense-fall-back is an ERROR naming each offending leaf,
    while engine construction itself still only warns."""
    api, _ = _deployed("phi3-mini-3.8b")
    packed = to_serving_params(api.init(jax.random.PRNGKey(0)), 8,
                               layout="packed")
    with pytest.warns(UserWarning, match="fall back"):
        eng = ServeEngine(api, packed, backend="bitplane")
    report = lint_engine(eng, prompt_len=8, n_slots=2, max_new=8)
    hits = [f for f in report.findings
            if f.rule == "bitplane-dense-fallback" and f.severity == "error"]
    assert hits and not report.ok
    assert any("wq" in f.path for f in hits)

    api2, sp = _deployed("phi3-mini-3.8b")
    clean = lint_engine(ServeEngine(api2, sp, backend="bitplane"),
                        prompt_len=8, n_slots=2, max_new=8)
    assert not [f for f in clean.findings
                if f.rule == "bitplane-dense-fallback"
                and f.severity == "error"]
