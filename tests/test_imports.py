"""Every module under src/repro must import.

A missing module (like the repro.dist regression this guards against) used
to surface as six scattered pytest collection errors; here it fails as one
named test per module instead.
"""
import importlib
import os
import pkgutil

import pytest

import repro


def _walk():
    names = ["repro"]
    for info in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        names.append(info.name)
    return sorted(names)


@pytest.mark.parametrize("name", _walk())
def test_module_imports(name):
    # launch.dryrun / launch.hillclimb overwrite XLA_FLAGS at import (their
    # entrypoints need 512 fake devices before jax init); don't let that
    # leak into the rest of the suite's environment.
    before = os.environ.get("XLA_FLAGS")
    try:
        importlib.import_module(name)
    finally:
        if before is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = before
