"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, assert shapes + finiteness (assignment requirement)."""
import jax
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.data import lm_batch_for
from repro.configs.base import ShapeCell
from repro.models.api import build
from repro.models.common import QuantConfig
from repro.optim import adamw, cosine_schedule
from repro.train import TrainState, build_train_step

ARCHS = sorted(REGISTRY)
CELL = ShapeCell("smoke", seq_len=32, global_batch=2, kind="train")


def _tiny(name):
    cfg = REGISTRY[name].tiny(dtype="float32")
    return cfg.with_quant(QuantConfig(mode="fake", n_bits=8, act_bits=8))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = _tiny(arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = lm_batch_for(cfg, CELL, step=0)
    loss, metrics = api.loss(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch):
    cfg = _tiny(arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    opt = adamw(weight_decay=0.0)
    step = build_train_step(lambda p, b: api.loss(p, b), opt,
                            cosine_schedule(1e-3, 10), donate=False)
    state = TrainState.create(params, opt)
    batch = lm_batch_for(cfg, CELL, step=0)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # no NaNs anywhere in updated params
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "granite-moe-3b-a800m",
                                  "rwkv6-1.6b", "zamba2-1.2b",
                                  "qwen2-vl-2b", "seamless-m4t-large-v2"])
def test_quantized_vs_unquantized_close_at_init(arch):
    """8-bit BWQ at init stays close to the unquantized forward."""
    cfg_q = _tiny(arch)
    cfg_f = cfg_q.with_quant(QuantConfig(mode="none"))
    batch = lm_batch_for(cfg_q, CELL, step=0)
    api_q, api_f = build(cfg_q), build(cfg_f)
    p_q = api_q.init(jax.random.PRNGKey(0))
    p_f = api_f.init(jax.random.PRNGKey(0))
    l_q, _ = api_q.loss(p_q, batch)
    l_f, _ = api_f.loss(p_f, batch)
    assert abs(float(l_q) - float(l_f)) < 0.35
