"""Sharded checkpoint format v2: collision-free key sanitization, crash-safe
atomic commit, async-error surfacing, GC edge cases (keep=0/1), structured
template-mismatch errors + partial restore, shard manifests + CK* contract
validation, elastic cross-mesh restore (subprocess, 2 devices), padded-
sharding numeric parity, and the direct checkpoint->serving cold-start that
never materializes the dense f32 tree."""
import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.ckpt.checkpoint as ckpt_mod
from repro.analysis import validate_checkpoint
from repro.ckpt import (CheckpointManager, CheckpointMismatchError,
                        CheckpointReader, restore_tree, save_tree)
from repro.ckpt.checkpoint import _sanitize


class _Mesh12:
    """Stand-in: fit_spec/chunking only read ``mesh.shape``, so a 2-way
    model axis is testable on one device."""
    shape = {"data": 1, "model": 2}


# ---------------------------------------------------------------------------
# key sanitization (regression: 'a b' and 'a_b' used to collide)
# ---------------------------------------------------------------------------

class TestSanitize:
    def test_injective_on_collision_prone_keys(self):
        assert _sanitize("['a b']") != _sanitize("['a_b']")
        assert _sanitize("['a/b']") != _sanitize("['a_b']")
        # underscore itself is escaped, so no crafted key can collide
        assert _sanitize("a_62") != _sanitize("ab")

    def test_roundtrip_keys_differing_only_in_punctuation(self):
        tree = {"a b": jnp.arange(3.0), "a_b": jnp.arange(3.0) * 10,
                "a/b": jnp.arange(3.0) * 100}
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "ck")
            save_tree(tree, p)
            out = restore_tree(
                jax.tree_util.tree_map(jnp.zeros_like, tree), p)
        for k in tree:
            np.testing.assert_array_equal(np.asarray(out[k]),
                                          np.asarray(tree[k]))


# ---------------------------------------------------------------------------
# crash-safe commit (regression: the old path rmtree'd the previous
# checkpoint before renaming the new one in)
# ---------------------------------------------------------------------------

class TestAtomicCommit:
    def test_crash_before_commit_preserves_previous(self, monkeypatch):
        tree1 = {"w": jnp.ones((4, 4))}
        tree2 = {"w": jnp.ones((4, 4)) * 2}
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "ck")
            save_tree(tree1, p)

            def boom(tmp):
                raise OSError("injected crash before commit")

            monkeypatch.setattr(ckpt_mod, "_fsync_tree", boom)
            with pytest.raises(OSError, match="injected"):
                save_tree(tree2, p)
            monkeypatch.undo()
            # the old checkpoint is untouched and fully readable
            out = restore_tree({"w": jnp.zeros((4, 4))}, p)
            np.testing.assert_array_equal(np.asarray(out["w"]), 1.0)
            # the aborted write left only quarantined .tmp debris
            debris = [n for n in os.listdir(d) if n != "ck"]
            assert all(".tmp." in n for n in debris) and debris

    def test_overwrite_commits_and_leaves_no_debris(self):
        tree1 = {"w": jnp.ones(3)}
        tree2 = {"w": jnp.ones(3) * 7}
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "ck")
            save_tree(tree1, p)
            save_tree(tree2, p)
            out = restore_tree({"w": jnp.zeros(3)}, p)
            np.testing.assert_array_equal(np.asarray(out["w"]), 7.0)
            assert os.listdir(d) == ["ck"]

    def test_manager_crash_then_recovery_sweeps_debris(self, monkeypatch):
        tree = {"w": jnp.arange(4.0)}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=3, use_async=False)
            mgr.save(1, tree)

            def boom(tmp):
                raise OSError("disk full")

            monkeypatch.setattr(ckpt_mod, "_fsync_tree", boom)
            with pytest.raises(RuntimeError, match="disk full"):
                mgr.save(2, tree)
            monkeypatch.undo()
            # the failed step never becomes visible
            assert mgr.latest_step() == 1
            mgr.save(3, tree)
            # recovery swept the crash debris
            assert sorted(os.listdir(d)) == ["step_1", "step_3"]


# ---------------------------------------------------------------------------
# async save errors (regression: they were swallowed silently)
# ---------------------------------------------------------------------------

class TestAsyncErrors:
    def test_wait_reraises_async_failure(self, monkeypatch):
        def boom(*a, **k):
            raise OSError("disk full")

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=3, use_async=True)
            monkeypatch.setattr(ckpt_mod, "save_tree", boom)
            mgr.save(1, {"w": jnp.ones(2)})
            with pytest.raises(RuntimeError, match="disk full"):
                mgr.wait()
            # the error is consumed: the manager is usable again
            monkeypatch.undo()
            mgr.wait()
            mgr.save(2, {"w": jnp.ones(2)})
            mgr.wait()
            assert mgr.latest_step() == 2

    def test_next_save_reraises_async_failure(self, monkeypatch):
        def boom(*a, **k):
            raise OSError("quota exceeded")

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=3, use_async=True)
            monkeypatch.setattr(ckpt_mod, "save_tree", boom)
            mgr.save(1, {"w": jnp.ones(2)})
            monkeypatch.undo()
            with pytest.raises(RuntimeError, match="quota exceeded"):
                mgr.save(2, {"w": jnp.ones(2)})


# ---------------------------------------------------------------------------
# GC edge cases (regression: keep=0 sliced dirs[:-0] == [] and kept all)
# ---------------------------------------------------------------------------

class TestGC:
    def test_keep_zero_retains_nothing(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=0, use_async=False)
            for step in (1, 2):
                mgr.save(step, {"a": jnp.ones(2)})
            assert mgr.latest_step() is None
            assert os.listdir(d) == []

    def test_keep_one_retains_only_latest(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=1, use_async=False)
            for step in (1, 2, 3):
                mgr.save(step, {"a": jnp.ones(2)})
            assert mgr.latest_step() == 3
            assert os.listdir(d) == ["step_3"]


# ---------------------------------------------------------------------------
# structured mismatch errors + partial restore (regression: bare KeyError)
# ---------------------------------------------------------------------------

class TestMismatch:
    def test_missing_and_extra_listed(self):
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "ck")
            save_tree({"a": jnp.ones(2), "b": jnp.ones(2) * 2}, p)
            template = {"b": jnp.zeros(2), "c": jnp.zeros(2)}
            with pytest.raises(CheckpointMismatchError) as ei:
                restore_tree(template, p)
            assert ei.value.missing == ["['c']"]
            assert ei.value.extra == ["['a']"]
            assert "partial=True" in str(ei.value)

    def test_partial_restore_keeps_template_values(self):
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "ck")
            save_tree({"a": jnp.ones(2), "b": jnp.ones(2) * 2}, p)
            template = {"b": jnp.zeros(2), "c": jnp.full((2,), 9.0)}
            out = restore_tree(template, p, partial=True)
            np.testing.assert_array_equal(np.asarray(out["b"]), 2.0)
            np.testing.assert_array_equal(np.asarray(out["c"]), 9.0)
            assert "a" not in out


# ---------------------------------------------------------------------------
# sharded manifests + CK* contract validation
# ---------------------------------------------------------------------------

def _sharded_save(d):
    tree = {"wo": np.arange(64, dtype=np.float32).reshape(8, 8),
            "b": np.arange(3, dtype=np.float32)}
    specs = {"wo": P("model", None), "b": P()}
    p = os.path.join(d, "ck")
    save_tree(tree, p, extra_meta={"step": 5}, mesh=_Mesh12(), specs=specs)
    return p, tree


class TestShardedFormat:
    def test_one_shard_file_per_host_and_reassembly(self):
        with tempfile.TemporaryDirectory() as d:
            p, tree = _sharded_save(d)
            names = sorted(os.listdir(p))
            assert names == ["META", "shard_00000-of-00002.npz",
                            "shard_00001-of-00002.npz"]
            with open(os.path.join(p, "META")) as f:
                meta = json.load(f)
            assert meta["format"] == 2 and meta["n_shards"] == 2
            assert meta["mesh_axes"] == {"data": 1, "model": 2}
            assert meta["manifest"]["['wo']"]["spec"] == [["model"], None]
            # each shard holds only its half of the row-parallel leaf
            s0 = np.load(os.path.join(p, names[1]))
            assert s0[_sanitize("['wo']")].shape == (4, 8)
            reader = CheckpointReader(p)
            np.testing.assert_array_equal(reader.read("['wo']"), tree["wo"])
            np.testing.assert_array_equal(reader.read("['b']"), tree["b"])
            assert reader.extra == {"step": 5}
            reader.close()
            out = restore_tree({"wo": jnp.zeros((8, 8)),
                                "b": jnp.zeros(3)}, p)
            np.testing.assert_array_equal(np.asarray(out["wo"]), tree["wo"])

    def test_validate_checkpoint_clean(self):
        with tempfile.TemporaryDirectory() as d:
            p, _ = _sharded_save(d)
            findings = validate_checkpoint(p)
            assert not [f for f in findings if f.severity == "error"]
            assert any(f.rule == "CK0" for f in findings)

    def test_validate_checkpoint_missing_shard_is_ck2(self):
        with tempfile.TemporaryDirectory() as d:
            p, _ = _sharded_save(d)
            os.remove(os.path.join(p, "shard_00001-of-00002.npz"))
            findings = validate_checkpoint(p)
            assert any(f.rule == "CK2" and f.severity == "error"
                       for f in findings)

    def test_validate_checkpoint_commit_debris_is_ck3(self):
        with tempfile.TemporaryDirectory() as d:
            p, _ = _sharded_save(d)
            os.makedirs(p + ".tmp.deadbeef")
            findings = validate_checkpoint(p)
            assert any(f.rule == "CK3" and f.severity == "warning"
                       for f in findings)

    def test_legacy_v1_checkpoint_still_readable(self):
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "ck")
            os.makedirs(p)
            arr = np.arange(6, dtype=np.float32).reshape(2, 3)
            np.savez(os.path.join(p, "arrays.npz"), **{"_'w'_": arr})
            with open(os.path.join(p, "META"), "w") as f:
                json.dump({"manifest": {"['w']": "_'w'_"},
                           "extra": {"step": 1}}, f)
            out = restore_tree({"w": jnp.zeros((2, 3))}, p)
            np.testing.assert_array_equal(np.asarray(out["w"]), arr)


# ---------------------------------------------------------------------------
# direct checkpoint -> serving cold-start (streamed, no dense f32 tree)
# ---------------------------------------------------------------------------

def _quant_setup(mode="bitplane"):
    from repro.configs import REGISTRY
    from repro.models.api import build
    from repro.models.common import QuantConfig
    cfg = REGISTRY["phi3-mini-3.8b"].tiny(dtype="float32").with_quant(
        QuantConfig(mode=mode, n_bits=8, act_bits=8))
    api = build(cfg)
    return cfg, api, api.init(jax.random.PRNGKey(0))


class TestColdStart:
    def test_streamed_deploy_peak_below_dense_and_bit_identical(self):
        from repro.serve.deploy import to_serving_params
        cfg, api, params = _quant_setup()
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "ck")
            save_tree(params, p)
            stats = {}
            sp = to_serving_params(p, 8, layout="bitplane",
                                   template=api.abstract_params(),
                                   stats=stats)
        # the whole point: the f32 tree is never resident at once
        assert 0 < stats["peak_host_bytes"] < stats["dense_tree_bytes"]
        ref = to_serving_params(params, 8, layout="bitplane")
        for a, b in zip(jax.tree_util.tree_leaves(sp),
                        jax.tree_util.tree_leaves(ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_trainstate_checkpoint_streams_params_only(self):
        from repro.optim import sgd
        from repro.serve.deploy import to_serving_params
        from repro.train.state import TrainState
        cfg, api, params = _quant_setup()
        state = TrainState.create(params,
                                  sgd(momentum=0.9, weight_decay=0.0), 0.0)
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "ck")
            save_tree(state, p)
            sp = to_serving_params(p, 8, layout="bitplane",
                                   template=api.abstract_params())
        ref = to_serving_params(params, 8, layout="bitplane")
        for a, b in zip(jax.tree_util.tree_leaves(sp),
                        jax.tree_util.tree_leaves(ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_cold_start_engine_generates(self):
        from repro.serve import ServeEngine
        from repro.serve.deploy import to_serving_params
        cfg, api, params = _quant_setup()
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "ck")
            save_tree(params, p)
            sp = to_serving_params(p, 8, layout="bitplane",
                                   template=api.abstract_params())
        eng = ServeEngine(api, sp, backend="bitplane")
        batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
        out = eng.generate(batch, max_new=4)
        assert out.shape == (2, 4)
        ref_eng = ServeEngine(api, to_serving_params(
            params, 8, layout="bitplane"), backend="bitplane")
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(ref_eng.generate(batch, max_new=4)))

    def test_resolve_ckpt_dir(self):
        from repro.launch.serve import resolve_ckpt_dir
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2, use_async=False)
            mgr.save(3, {"w": jnp.ones(2)})
            mgr.save(7, {"w": jnp.ones(2)})
            step7 = os.path.join(d, "step_7")
            assert resolve_ckpt_dir(d) == step7
            assert resolve_ckpt_dir(d, step=3) == os.path.join(d, "step_3")
            assert resolve_ckpt_dir(step7) == step7
            with pytest.raises(SystemExit):
                resolve_ckpt_dir(d, step=9)          # no such step
            with tempfile.TemporaryDirectory() as empty:
                with pytest.raises(SystemExit):
                    resolve_ckpt_dir(empty)          # no checkpoints at all


# ---------------------------------------------------------------------------
# elastic cross-mesh restore + padded numeric parity (2 devices, subprocess)
# ---------------------------------------------------------------------------

_ELASTIC_SCRIPT = r"""
import dataclasses, json, os, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.configs import REGISTRY
from repro.models.api import build
from repro.models.common import QuantConfig
from repro.dist.sharding import use_mesh
from repro.launch.mesh import make_mesh
from repro.ckpt import restore_tree, save_tree

assert jax.device_count() == 2, jax.device_count()
cfg = REGISTRY["phi3-mini-3.8b"].tiny(dtype="float32").with_quant(
    QuantConfig(mode="fake", n_bits=8, act_bits=8))
api = build(cfg)
params = api.init(jax.random.PRNGKey(0))
template = jax.tree_util.tree_map(jnp.zeros_like, params)

def same(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

with tempfile.TemporaryDirectory() as d:
    # save under a model-parallel 2-device mesh -> 2 shard files
    mesh_a = make_mesh((1, 2), ("data", "model"))
    p1 = os.path.join(d, "sharded")
    with use_mesh(mesh_a):
        save_tree(params, p1, mesh=mesh_a)
    with open(os.path.join(p1, "META")) as f:
        assert json.load(f)["n_shards"] == 2
    # restore onto a *different* live mesh (elastic), and onto no mesh
    mesh_b = make_mesh((2, 1), ("data", "model"))
    with use_mesh(mesh_b):
        same(params, restore_tree(template, p1, mesh=mesh_b))
    same(params, restore_tree(template, p1))
    # the reverse direction: unsharded save -> sharded restore
    p2 = os.path.join(d, "mono")
    save_tree(params, p2)
    with use_mesh(mesh_a):
        same(params, restore_tree(template, p2, mesh=mesh_a))
print("ELASTIC_OK")

# padded sharding: a prime vocab (251) cannot divide the 2-way model axis;
# the engine zero-pads at placement and slices back in-graph, so tokens
# must match the unsharded engine exactly
from repro.serve import ServeEngine
cfgp = dataclasses.replace(cfg, vocab=251)
apip = build(cfgp)
pp = apip.init(jax.random.PRNGKey(0))
batch = {"tokens": jax.random.randint(
    jax.random.PRNGKey(1), (4, 8), 0, 251).astype(jnp.int32)}
ref = np.asarray(ServeEngine(apip, pp).generate(batch, max_new=6))
for shape in [(1, 2), (2, 1)]:
    with use_mesh(make_mesh(shape, ("data", "model"))):
        out = np.asarray(ServeEngine(apip, pp).generate(batch, max_new=6))
    assert (out == ref).all(), shape
print("PADDED_OK")
"""


def test_elastic_restore_and_padded_parity_two_devices():
    """Checkpoints written under one mesh restore bit-identically under
    another (and under none), and padded parameter sharding of an
    indivisible vocab decodes token-identically to single-device."""
    env = dict(os.environ,
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")] +
                   sys.path))
    out = subprocess.run([sys.executable, "-c", _ELASTIC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ELASTIC_OK" in out.stdout
    assert "PADDED_OK" in out.stdout
