"""Fused paged-attention decode kernel: unit, oracle, lint, and parity.

* ``page_coords`` / ``paged_gather`` edge cases — clamp-into-last-block
  past the table end, trash-page (page 0) routing, (B,) vs scalar fill
  levels — previously covered only indirectly through serving parity;
* kernel vs ``paged_attention_ref`` allclose across kv-bits, GQA ratios,
  ragged fill levels, sliding windows, softcap, and ``block_kv`` tiles;
* graph-lint footprint census: the fused decode jaxpr holds neither a
  full-width KV gather nor an f32 KV materialization (``kv-clean``), and
  a forced gather fallback under a fused engine is an ERROR;
* decode token parity: greedy decodes are bit-identical across
  ``attn_backend`` in {gather, fused, ref}, contiguous and paged, at
  kv_bits in {8, 4} (phi3 fast tier; granite-moe in the slow tier).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import paged_attention_ref
from repro.models.api import build
from repro.models.attention import (PAGED_ATTN_BACKENDS, page_coords,
                                    paged_attn_backend, paged_gather,
                                    quantize_kv)
from repro.models.common import QuantConfig
from repro.serve import Request, SamplingParams, ServeEngine


# ---------------------------------------------------------------------------
# page_coords / paged_gather edge cases
# ---------------------------------------------------------------------------

def test_page_coords_basic_mapping():
    table = jnp.asarray([[3, 1], [2, 5]], jnp.int32)     # (B=2, nb=2)
    pids, offs = page_coords(table, jnp.asarray([0, 5]), seq=2, page=4)
    # slot 0 writes positions 0,1 -> block 0 (page 3), offsets 0,1
    assert pids[0].tolist() == [3, 3] and offs[0].tolist() == [0, 1]
    # slot 1 writes positions 5,6 -> block 1 (page 5), offsets 1,2
    assert pids[1].tolist() == [5, 5] and offs[1].tolist() == [1, 2]


def test_page_coords_past_table_end_is_inert():
    table = jnp.asarray([[7, 9]], jnp.int32)             # nb=2, page=4: T=8
    pids, offs = page_coords(table, 7, seq=2, page=4)
    # position 7 is the last real slot and lands in the last block;
    # position 8 is past the table end — callers only ever send masked
    # scratch writes there, so its page id must never alias a live page
    # other than the clamp target (scatter drops out-of-range ids)
    assert int(pids[0, 0]) == 9 and offs[0].tolist() == [3, 0]
    tail = int(pids[0, 1])
    assert tail == 9 or not (0 <= tail <= 8)
    # the write path stays inert: scattering through these coords must not
    # touch any page other than the last block (out-of-range ids drop)
    pool = jnp.zeros((10, 4), jnp.float32)
    wrote = pool.at[pids[0], offs[0]].set(1.0)
    assert float(wrote[:9].sum()) == 0.0


def test_page_coords_scalar_vs_vector_fill_levels():
    table = jnp.asarray([[4, 2], [6, 8]], jnp.int32)
    ps, os_ = page_coords(table, 3, seq=2, page=4)
    pv, ov = page_coords(table, jnp.asarray([3, 3]), seq=2, page=4)
    np.testing.assert_array_equal(np.asarray(ps), np.asarray(pv))
    np.testing.assert_array_equal(np.asarray(os_), np.asarray(ov))


def test_page_coords_trash_page_for_unallocated_blocks():
    # a parked slot's table is all zeros: every write routes to page 0
    table = jnp.zeros((1, 3), jnp.int32)
    pids, _ = page_coords(table, 5, seq=3, page=4)
    assert pids.tolist() == [[0, 0, 0]]


def test_paged_gather_layout_and_trash_masking():
    pool = jnp.arange(5 * 2 * 3, dtype=jnp.float32).reshape(5, 2, 3)
    table = jnp.asarray([[2, 0], [4, 1]], jnp.int32)
    out = paged_gather(pool, table)                      # (B, nb*page, 3)
    assert out.shape == (2, 4, 3)
    np.testing.assert_array_equal(np.asarray(out[0, :2]),
                                  np.asarray(pool[2]))
    np.testing.assert_array_equal(np.asarray(out[0, 2:]),
                                  np.asarray(pool[0]))  # trash page content
    np.testing.assert_array_equal(np.asarray(out[1, 2:]),
                                  np.asarray(pool[1]))


# ---------------------------------------------------------------------------
# kernel vs reference oracle
# ---------------------------------------------------------------------------

def _pool_case(key, b, kv, g, dh, page, nb, bits):
    """Random page pool + table + ragged fill levels for one case."""
    n_pages = 1 + b * nb
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, kv, g, dh), jnp.float32)
    kf = jax.random.normal(ks[1], (n_pages, page, kv, dh), jnp.float32)
    vf = jax.random.normal(ks[2], (n_pages, page, kv, dh), jnp.float32)
    if bits < 32:
        kq, ksc = quantize_kv(kf, bits)
        vq, vsc = quantize_kv(vf, bits)
    else:
        kq, vq, ksc, vsc = kf, vf, None, None
    table = jnp.arange(1, 1 + b * nb, dtype=jnp.int32).reshape(b, nb)
    kv_len = (jax.random.randint(jax.random.fold_in(key, 9), (b,), 1,
                                 nb * page + 1).astype(jnp.int32))
    return q, kq, vq, ksc, vsc, table, kv_len


@pytest.mark.parametrize("bits,g,window,softcap,block_kv", [
    (8, 1, None, 0.0, 1),
    (8, 4, None, 0.0, 2),          # GQA grouping + kv-head tiling
    (4, 2, None, 0.0, 1),          # nibble-packed int4 in-kernel unpack
    (32, 2, None, 0.0, 1),         # float pool (paged, unquantized)
    (8, 2, 5, 30.0, 1),            # sliding window + softcap
])
def test_kernel_matches_ref(bits, g, window, softcap, block_kv):
    b, kv, dh, page, nb = 2, 4, 16, 4, 3
    case = _pool_case(jax.random.PRNGKey(bits * 7 + g), b, kv, g, dh,
                      page, nb, bits)
    got = paged_attention(*case, window=window, softcap=softcap,
                          block_kv=block_kv)
    want = paged_attention_ref(*case, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_kernel_trash_page_stays_inert():
    """Blocks past a slot's fill level point at real-but-stale pages or
    the trash page; both must be masked identically."""
    b, kv, g, dh, page, nb = 1, 2, 2, 8, 4, 3
    case = _pool_case(jax.random.PRNGKey(0), b, kv, g, dh, page, nb, 8)
    q, kq, vq, ksc, vsc, table, _ = case
    kv_len = jnp.asarray([page], jnp.int32)      # only block 0 is live
    trash_table = table.at[0, 1:].set(0)         # blocks 1.. -> trash page
    a = paged_attention(q, kq, vq, ksc, vsc, table, kv_len)
    t = paged_attention(q, kq, vq, ksc, vsc, trash_table, kv_len)
    np.testing.assert_allclose(np.asarray(a), np.asarray(t), atol=1e-6)


# ---------------------------------------------------------------------------
# footprint census (graph lint)
# ---------------------------------------------------------------------------

def _census_engine(kv_bits=8):
    cfg = REGISTRY["phi3-mini-3.8b"].tiny(dtype="float32").with_quant(
        QuantConfig(mode="fake", n_bits=8, act_bits=8))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return ServeEngine(api, params, kv_quant_bits=kv_bits,
                       attn_backend="fused", page_size=4)


def _decode_args(eng, n_slots=2, max_len=24, page_size=4):
    batch = {"tokens": jax.ShapeDtypeStruct((1, 8), jnp.int32)}
    state = jax.eval_shape(
        lambda p, b: eng.api.init_decode_state(p, b, n_slots, max_len,
                                               page_size=page_size),
        eng.params, batch)
    tokens = jax.ShapeDtypeStruct((n_slots, 1), jnp.int32)
    index = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
    return eng.params, tokens, state, index


@pytest.mark.parametrize("kv_bits", [8, 4])
def test_fused_decode_jaxpr_is_kv_clean(kv_bits):
    from repro.analysis.graph_lint import lint_traced_fn
    eng = _census_engine(kv_bits)
    findings = lint_traced_fn(eng.api.decode_step, _decode_args(eng),
                              fn_name="decode", backend="dense",
                              attn_backend="fused")
    assert not [f for f in findings if f.severity == "error"], \
        [f.format() for f in findings]
    assert any(f.rule == "kv-clean" for f in findings)


def test_gather_fallback_under_fused_is_error():
    from repro.analysis.graph_lint import lint_traced_fn
    eng = _census_engine(8)

    def gather_decode(p, t, s, i):
        with paged_attn_backend("gather"):       # the silent fallback
            return eng.api.decode_step(p, t, s, i)

    findings = lint_traced_fn(gather_decode, _decode_args(eng),
                              fn_name="decode", backend="dense",
                              attn_backend="fused")
    errs = {f.rule for f in findings if f.severity == "error"}
    assert {"kv-full-width-gather", "kv-dequant-materialization"} <= errs


def test_gather_backend_is_sanctioned():
    from repro.analysis.graph_lint import lint_traced_fn
    eng = _census_engine(8)
    findings = lint_traced_fn(eng.api.decode_step, _decode_args(eng),
                              fn_name="decode", backend="dense",
                              attn_backend="gather")
    assert not [f for f in findings if f.severity == "error"]
    assert any(f.rule.startswith("sanctioned-kv") for f in findings)


# ---------------------------------------------------------------------------
# PA* contracts
# ---------------------------------------------------------------------------

def test_pa_contracts_flag_bad_pools():
    from repro.analysis.contracts import validate_decode_state
    pool = {"k": jnp.zeros((1, 4, 2, 2, 8), jnp.int8),
            "v": jnp.zeros((1, 4, 2, 2, 8), jnp.int8),
            "k_scale": jnp.zeros((1, 4, 2, 2), jnp.float32),
            "v_scale": jnp.zeros((1, 4, 2, 2), jnp.float32)}
    table = jnp.zeros((1, 3, 2), jnp.int32)
    good = {"cache": {"layer": {"pages": pool, "table": table}}}
    assert not [f for f in validate_decode_state(good, n_slots=3)
                if f.severity == "error"]
    # PA1: k/v dtype disagreement
    bad = {"cache": {"layer": {
        "pages": dict(pool, v=pool["v"].astype(jnp.uint8)),
        "table": table}}}
    assert any(f.rule == "PA1" for f in validate_decode_state(bad)
               if f.severity == "error")
    # PA2: pool with only the trash page
    bad = {"cache": {"layer": {
        "pages": {k: v[:, :1] for k, v in pool.items()}, "table": table}}}
    assert any(f.rule == "PA2" for f in validate_decode_state(bad)
               if f.severity == "error")
    # PA3: live page after a trash-page hole
    holey = table.at[0, 0, 1].set(2)          # row [0, 2]: hole at block 0
    bad = {"cache": {"layer": {"pages": pool, "table": holey}}}
    assert any(f.rule == "PA3" for f in validate_decode_state(bad)
               if f.severity == "error")


# ---------------------------------------------------------------------------
# decode token parity across attn backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,kv_bits", [
    ("phi3-mini-3.8b", 8),
    ("phi3-mini-3.8b", 4),
    pytest.param("granite-moe-3b-a800m", 8, marks=pytest.mark.slow),
    pytest.param("granite-moe-3b-a800m", 4, marks=pytest.mark.slow),
])
def test_decode_token_parity_across_attn_backends(arch, kv_bits):
    cfg = REGISTRY[arch].tiny(dtype="float32").with_quant(
        QuantConfig(mode="fake", n_bits=8, act_bits=8))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(3), (2, 8), 0, cfg.vocab).astype(jnp.int32)}
    outs = {}
    for ab in PAGED_ATTN_BACKENDS:
        eng = ServeEngine(api, params, kv_quant_bits=kv_bits,
                          attn_backend=ab)
        outs[ab] = np.asarray(eng.generate(batch, max_new=6))
        reqs = [Request(uid=i,
                        inputs={"tokens": batch["tokens"][i:i + 1]},
                        sampling=SamplingParams(max_new_tokens=5),
                        arrival=i)
                for i in range(2)]
        res = eng.serve(reqs, n_slots=2, page_size=4)
        outs[ab + "_paged"] = [r.tokens for r in res]
    for ab in ("fused", "ref"):
        np.testing.assert_array_equal(outs[ab], outs["gather"])
        assert outs[ab + "_paged"] == outs["gather_paged"]
