"""Static serving-graph analysis (repro.analysis) — the lint subsystem.

* contract validator NEGATIVE paths: each corruption of a deployed tree
  (trailing stack dims, wrong scale-LUT shape, non-binary / non-monotone
  bitplane mask, truncated sign plane, orphaned block-table page ids)
  produces path-qualified error findings, never a crash — and the engine
  refuses to construct on such a tree;
* graph lint acceptance: an injected whole-tree dequant under
  ``backend="pallas"`` is a lint FAILURE (dequant-materialization /
  payload-convert), while the real engine lints clean on both wire
  formats;
* ``chunk_widths`` stays in lockstep with ``Scheduler._plan_chunks``,
  chunk-for-chunk, and the footprint census flags recompile blowups;
* sharding lint surfaces every ``fit_spec`` drop (satellite: the
  structured ShardingDropWarning) against deviceless meshes;
* decode-state donation is verified via ``Lowered.args_info`` and the
  ``missing-donation`` finding fires when donation is disabled;
* HLO-text helpers: ``input_output_aliases`` / ``shape_census``.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis import (ShapeOnlyMesh, chunk_widths,
                            check_decode_donation, fallback_leaf_paths,
                            footprint_findings, generate_signatures,
                            lint_engine, lint_sharding, lint_traced_fn,
                            production_mesh_shape, serve_signatures,
                            validate_decode_state, validate_scheduler,
                            validate_serving_tree)
from repro.configs import REGISTRY
from repro.dist.hlo_analysis import input_output_aliases, shape_census
from repro.dist.sharding import (ShardingDropWarning, collect_spec_events,
                                 fit_spec)
from repro.models import common as common_mod
from repro.models.api import build
from repro.models.common import (QuantConfig, make_weight, matmul_backend,
                                 qdense, qmatmul)
from repro.serve import Request, SamplingParams, ServeEngine
from repro.serve.deploy import (BitplaneServingWeight, ServingWeight,
                                to_serving_params)

QC = QuantConfig(mode="fake", n_bits=8, act_bits=8)
_DEPLOYED = (ServingWeight, BitplaneServingWeight)


@pytest.fixture(scope="module")
def phi3():
    cfg = REGISTRY["phi3-mini-3.8b"].tiny(dtype="float32").with_quant(QC)
    api = build(cfg)
    return api, api.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def packed_params(phi3):
    return to_serving_params(phi3[1], 8, layout="packed")


@pytest.fixture(scope="module")
def bitplane_params(phi3):
    return to_serving_params(phi3[1], 8, layout="bitplane")


def _mutate_one(params, leaf_type, fn):
    """Corrupt the first ``leaf_type`` leaf of the tree with ``fn``."""
    hit = []

    def conv(x):
        if isinstance(x, leaf_type) and not hit:
            hit.append(True)
            return fn(x)
        return x

    out = jax.tree_util.tree_map(
        conv, params, is_leaf=lambda x: isinstance(x, _DEPLOYED))
    assert hit, f"tree holds no {leaf_type.__name__} leaf"
    return out


def _errors(findings, rule=None):
    return [f for f in findings if f.severity == "error"
            and (rule is None or f.rule == rule)]


# ---------------------------------------------------------------------------
# contract validator: clean trees and negative paths
# ---------------------------------------------------------------------------

def test_deployed_trees_validate_clean(packed_params, bitplane_params):
    assert not _errors(validate_serving_tree(packed_params))
    assert not _errors(validate_serving_tree(bitplane_params))


def test_undeployed_tree_is_vacuous(phi3):
    findings = validate_serving_tree(phi3[1])
    assert not _errors(findings)
    assert any(f.rule == "SW0" and f.severity == "info" for f in findings)


BP_CORRUPTIONS = [
    # (name, rule, path suffix, mutation)
    ("trailing-stack-dims", "BP1", ".planes",
     lambda bp: dataclasses.replace(
         bp, planes=jnp.moveaxis(bp.planes, 0, -1))),
    ("wrong-mask-lut-shape", "BP2", ".mask",
     lambda bp: dataclasses.replace(bp, mask=bp.mask[..., :1])),
    ("non-binary-mask", "BP2", ".mask",
     lambda bp: dataclasses.replace(bp, mask=bp.mask * 2.0)),
    ("non-monotone-mask", "BP2", ".mask",
     lambda bp: dataclasses.replace(
         bp, mask=bp.mask.at[..., 0, :, :].set(0.0))),
    ("truncated-sign-plane", "BP1", ".sign",
     lambda bp: dataclasses.replace(bp, sign=bp.sign[..., :-1, :])),
]


@pytest.mark.parametrize("name,rule,suffix,mutate", BP_CORRUPTIONS,
                         ids=[c[0] for c in BP_CORRUPTIONS])
def test_bitplane_corruption_is_one_diagnostic(bitplane_params, name, rule,
                                               suffix, mutate):
    """Each corruption: path-qualified error finding(s), no crash."""
    bad = _mutate_one(bitplane_params, BitplaneServingWeight, mutate)
    findings = validate_serving_tree(bad)          # must not raise
    errs = _errors(findings)
    assert len(errs) == 1, [f.format() for f in errs]
    assert errs[0].rule == rule
    assert errs[0].path.endswith(suffix)


PACKED_CORRUPTIONS = [
    ("wrong-scale-lut-shape", "SW2", ".scale",
     lambda sw: dataclasses.replace(sw, scale=sw.scale[..., :1])),
    ("wrong-payload-dtype", "SW4", ".w_int",
     lambda sw: dataclasses.replace(
         sw, w_int=sw.w_int.astype(jnp.int32))),
    ("trailing-stack-dims", "SW4", ".w_int",
     lambda sw: dataclasses.replace(
         sw, w_int=jnp.moveaxis(sw.w_int, 0, -1))),
]


@pytest.mark.parametrize("name,rule,suffix,mutate", PACKED_CORRUPTIONS,
                         ids=[c[0] for c in PACKED_CORRUPTIONS])
def test_packed_corruption_is_diagnosed(packed_params, name, rule, suffix,
                                        mutate):
    bad = _mutate_one(packed_params, ServingWeight, mutate)
    findings = validate_serving_tree(bad)
    errs = _errors(findings, rule)
    assert errs, [f.format() for f in findings]
    assert all(f.path.endswith(suffix) for f in errs)


def test_uninterpretable_leaf_is_sw0_not_crash(packed_params):
    bad = _mutate_one(packed_params, ServingWeight,
                      lambda sw: dataclasses.replace(sw, shape=None))
    findings = validate_serving_tree(bad)          # must not raise
    assert _errors(findings)


def test_engine_refuses_corrupt_tree(phi3, bitplane_params):
    api, _ = phi3
    bad = _mutate_one(bitplane_params, BitplaneServingWeight,
                      lambda bp: dataclasses.replace(bp, mask=bp.mask * 2.0))
    with pytest.raises(ValueError, match="serving contract"):
        ServeEngine(api, bad, backend="bitplane")
    # validate=False restores the old construct-then-crash behavior
    eng = ServeEngine(api, bad, backend="bitplane", validate=False)
    assert eng.backend == "bitplane"


# ---------------------------------------------------------------------------
# paged decode-state validation
# ---------------------------------------------------------------------------

def _paged_state(table):
    pages = {"k": np.zeros((1, 8, 4, 2, 3), np.float32),
             "v": np.zeros((1, 8, 4, 2, 3), np.float32)}
    return {"cache": {"layer0": {"table": table, "pages": pages}}}


def test_paged_state_clean():
    table = np.zeros((1, 2, 4), np.int32)
    assert not _errors(validate_decode_state(_paged_state(table), n_slots=2))


def test_orphaned_page_ids_are_pc2():
    table = np.zeros((1, 2, 4), np.int32)
    table[0, 1, 2] = 99                            # pool has 8 pages
    findings = validate_decode_state(_paged_state(table), n_slots=2)
    errs = _errors(findings, "PC2")
    assert len(errs) == 1
    assert "orphaned" in errs[0].message and "99" in errs[0].message
    assert errs[0].path.endswith("['table']")


def test_shared_page_is_pc2_warning():
    table = np.zeros((1, 2, 4), np.int32)
    table[0, 0, 0] = table[0, 1, 0] = 3            # two slots own page 3
    findings = validate_decode_state(_paged_state(table), n_slots=2)
    assert not _errors(findings)
    assert any(f.severity == "warning" and f.rule == "PC2"
               for f in findings)


def test_quantized_pool_needs_scales():
    pages = {"k": np.zeros((1, 8, 4, 2, 3), np.int8),
             "v": np.zeros((1, 8, 4, 2, 3), np.int8)}
    state = {"cache": {"l": {"table": np.zeros((1, 2, 4), np.int32),
                             "pages": pages}}}
    assert _errors(validate_decode_state(state, n_slots=2), "PC3")


def test_wrong_slot_count_is_pc1():
    table = np.zeros((1, 3, 4), np.int32)
    assert _errors(validate_decode_state(_paged_state(table), n_slots=2),
                   "PC1")


def test_refcounted_shared_page_is_not_pc2():
    """Multi-slot ownership is deliberate when the prefix cache's
    refcount ledger books the page — PC2 stays silent."""
    table = np.zeros((1, 2, 4), np.int32)
    table[0, 0, 0] = table[0, 1, 0] = 3
    findings = validate_decode_state(_paged_state(table), n_slots=2,
                                     refcounts={3: 2})
    assert not [f for f in findings if f.rule == "PC2"]


# ---------------------------------------------------------------------------
# scheduler ledger validation (PX1-PX3)
# ---------------------------------------------------------------------------

def _ledger_sched():
    """Duck-typed scheduler fixture: slot 0 aliases one registered shared
    page and owns one private page, slot 1 is free — every ledger closes."""
    import types

    from repro.serve.scheduler import PageAllocator, PrefixCache, _Slot
    alloc = PageAllocator(16)
    shared, private = alloc.alloc(2)
    pc = PrefixCache()
    pc.register(b"h0", shared)
    slot = _Slot(req=None, index=6, last_tok=0, generated=[],
                 admitted_tick=0, pages=[private], shared_pages=[shared],
                 prefix_hashes=[b"h0"])
    tables = np.zeros((2, 4), np.int32)
    tables[0, :2] = [shared, private]
    return types.SimpleNamespace(paged=True, page_size=4, n_slots=2,
                                 tables=tables, slots=[slot, None],
                                 allocator=alloc, prefix_cache=pc)


def test_scheduler_ledger_clean():
    assert not validate_scheduler(_ledger_sched())


def test_refcount_mismatch_is_px1():
    sched = _ledger_sched()
    sched.prefix_cache.acquire(1)          # phantom reference, no aliaser
    errs = _errors(validate_scheduler(sched), "PX1")
    assert errs and "refcount" in errs[0].message


def test_unregistered_shared_page_is_px1():
    sched = _ledger_sched()
    pc = sched.prefix_cache
    page = pc._page_of.pop(b"h0")          # drop the registry entry only
    pc._hash_of.pop(page), pc._refs.pop(page)
    assert any("not registered" in f.message
               for f in _errors(validate_scheduler(sched), "PX1"))


def test_double_owned_page_is_px1():
    from repro.serve.scheduler import _Slot
    sched = _ledger_sched()
    thief = _Slot(req=None, index=4, last_tok=0, generated=[],
                  admitted_tick=1, pages=[sched.slots[0].pages[0]])
    sched.slots[1] = thief
    sched.tables[1, 0] = thief.pages[0]
    assert any("more than once" in f.message
               for f in _errors(validate_scheduler(sched), "PX1"))


def test_allocator_drift_is_px1():
    sched = _ledger_sched()
    sched.allocator.alloc(1)               # drawn but booked nowhere
    assert any("allocator" in f.message
               for f in _errors(validate_scheduler(sched), "PX1"))


def test_write_frontier_inside_shared_region_is_px2():
    sched = _ledger_sched()
    sched.slots[0].index = 3               # shared region is [0, 4)
    assert _errors(validate_scheduler(sched), "PX2")


def test_stale_parked_row_is_px3():
    sched = _ledger_sched()
    sched.tables[1, 0] = 5                 # free slot still references it
    assert _errors(validate_scheduler(sched), "PX3")


def test_table_ledger_mismatch_is_px3():
    sched = _ledger_sched()
    sched.tables[0, [0, 1]] = sched.tables[0, [1, 0]]   # swapped order
    assert _errors(validate_scheduler(sched), "PX3")


def test_nonpaged_scheduler_validates_trivially():
    import types
    sched = types.SimpleNamespace(paged=False, tables=None)
    assert not validate_scheduler(sched)


# ---------------------------------------------------------------------------
# graph lint: injected violations are lint FAILURES; real engine is clean
# ---------------------------------------------------------------------------

def test_injected_dequant_is_lint_failure(phi3, packed_params):
    """Acceptance: dense-compose under backend='pallas' must FAIL."""
    api, _ = phi3
    batch = {"tokens": jax.ShapeDtypeStruct((1, 8), jnp.int32)}

    def bad_prefill(p, b):
        dense = jax.tree_util.tree_map(
            lambda x: qdense(x, jnp.float32), p,
            is_leaf=lambda x: isinstance(x, _DEPLOYED))
        return api.prefill(dense, b, extra_slots=64)

    findings = lint_traced_fn(bad_prefill, (packed_params, batch),
                              fn_name="prefill", backend="pallas")
    assert _errors(findings, "dequant-materialization")
    assert _errors(findings, "payload-convert")


def test_same_dequant_is_sanctioned_under_dense(phi3, packed_params):
    api, _ = phi3
    batch = {"tokens": jax.ShapeDtypeStruct((1, 8), jnp.int32)}

    def bad_prefill(p, b):
        dense = jax.tree_util.tree_map(
            lambda x: qdense(x, jnp.float32), p,
            is_leaf=lambda x: isinstance(x, _DEPLOYED))
        return api.prefill(dense, b, extra_slots=64)

    findings = lint_traced_fn(bad_prefill, (packed_params, batch),
                              fn_name="prefill", backend="dense")
    assert not _errors(findings)
    assert any(f.rule == "sanctioned-dequant" for f in findings)


def test_lint_engine_clean_packed_pallas(phi3, packed_params):
    eng = ServeEngine(phi3[0], packed_params, backend="pallas")
    rep = lint_engine(eng, prompt_len=8, n_slots=2, max_new=8)
    assert rep.ok, rep.format()
    assert any(f.pass_name == "graph" and f.rule == "clean"
               for f in rep.findings)
    assert any(f.rule == "donation-ok" for f in rep.findings)
    assert rep.context["backend"] == "pallas"


def test_lint_engine_clean_bitplane(phi3, bitplane_params):
    eng = ServeEngine(phi3[0], bitplane_params, backend="bitplane")
    rep = lint_engine(eng, prompt_len=8, n_slots=2, max_new=8)
    assert rep.ok, rep.format()
    assert any(f.pass_name == "graph" and f.rule == "clean"
               for f in rep.findings)


def test_lint_engine_corrupt_mask_is_failure(phi3, bitplane_params):
    """Acceptance: a corrupted bitplane mask is a lint FAILURE."""
    bad = _mutate_one(bitplane_params, BitplaneServingWeight,
                      lambda bp: dataclasses.replace(bp, mask=bp.mask * 2.0))
    eng = ServeEngine(phi3[0], bad, backend="bitplane", validate=False)
    rep = lint_engine(eng, prompt_len=8, n_slots=2, max_new=8)
    assert not rep.ok
    assert _errors(rep.findings, "BP2")
    assert "FAIL" in rep.summary()


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "granite-moe-3b-a800m",
                                  "seamless-m4t-large-v2"])
@pytest.mark.parametrize("backend,layout", [("pallas", "packed"),
                                            ("bitplane", "bitplane")])
@pytest.mark.parametrize("bits", [8, 4])
def test_lint_matrix_clean(arch, backend, layout, bits):
    """Acceptance matrix: every family x kernel backend x precision lints
    clean (dense/ref are sanctioned by construction; the packed backends
    are where materialization would be a regression)."""
    cfg = REGISTRY[arch].tiny(dtype="float32").with_quant(QC)
    api = build(cfg)
    params = to_serving_params(api.init(jax.random.PRNGKey(0)), bits,
                               layout=layout)
    eng = ServeEngine(api, params, backend=backend)
    rep = lint_engine(eng, prompt_len=8, n_slots=2, max_new=8)
    assert rep.ok, rep.format()


# ---------------------------------------------------------------------------
# bitplane dense-fallback surfacing (satellite)
# ---------------------------------------------------------------------------

def test_fallback_leaf_paths(packed_params, bitplane_params):
    assert fallback_leaf_paths(packed_params, "bitplane")
    assert fallback_leaf_paths(packed_params, "pallas") == []
    assert fallback_leaf_paths(bitplane_params, "bitplane") == []


def test_engine_warns_on_packed_under_bitplane(phi3, packed_params):
    with pytest.warns(UserWarning, match="fall back"):
        ServeEngine(phi3[0], packed_params, backend="bitplane")


def test_qmatmul_warns_once_on_bitplane_fallback():
    sw = to_serving_params(
        {"w": make_weight(jax.random.PRNGKey(0), (32, 16), QC)}, 8)["w"]
    assert isinstance(sw, ServingWeight)
    x = jnp.ones((2, 32))
    common_mod._WARNED_FALLBACKS.clear()
    with pytest.warns(UserWarning, match="falls back"):
        with matmul_backend("bitplane"):
            y = qmatmul(x, sw)
    assert y.shape == (2, 16)
    with warnings.catch_warnings():                # second call is silent
        warnings.simplefilter("error")
        with matmul_backend("bitplane"):
            qmatmul(x, sw)


def test_fallback_lint_is_warning_not_error(phi3, packed_params):
    api, _ = phi3
    batch = {"tokens": jax.ShapeDtypeStruct((1, 8), jnp.int32)}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        findings = lint_traced_fn(
            lambda p, b: api.prefill(p, b, extra_slots=64),
            (packed_params, batch), fn_name="prefill", backend="bitplane")
    assert not _errors(findings)
    assert any(f.rule == "bitplane-dense-fallback"
               and f.severity == "warning" for f in findings)


# ---------------------------------------------------------------------------
# donation
# ---------------------------------------------------------------------------

def test_missing_donation_is_lint_failure(phi3, packed_params):
    api, _ = phi3
    eng = ServeEngine(api, packed_params, backend="pallas",
                      donate_state=False)
    batch = {"tokens": jax.ShapeDtypeStruct((1, 8), jnp.int32)}
    state = jax.eval_shape(
        lambda p, b: api.init_decode_state(p, b, 2, 16), eng.params, batch)
    tokens = jax.ShapeDtypeStruct((2, 1), jnp.int32)
    index = jax.ShapeDtypeStruct((2,), jnp.int32)
    findings = check_decode_donation(eng, tokens, state, index)
    assert _errors(findings, "missing-donation")


# ---------------------------------------------------------------------------
# compile footprint
# ---------------------------------------------------------------------------

def test_chunk_widths_match_scheduler(phi3, packed_params):
    """chunk_widths must mirror Scheduler._plan_chunks chunk-for-chunk."""
    api, _ = phi3
    eng = ServeEngine(api, packed_params, backend="pallas", prefill_chunk=8)
    for p in (5, 8, 11, 16, 21):
        req = Request(uid=0,
                      inputs={"tokens": jnp.zeros((1, p), jnp.int32)},
                      sampling=SamplingParams(max_new_tokens=8))
        sched = eng.make_scheduler([req], n_slots=2)
        plan = sched._plan_chunks(req)
        got = [(b["tokens"].shape[1], start) for b, start, _col in plan]
        want = chunk_widths(p, sched.prefill_chunk, sched.total_len,
                            family=api.cfg.family)
        assert got == want, f"p={p}: {got} != {want}"


def test_footprint_census_and_blowup():
    # 12 distinct widths through the legacy monolithic path: 25 signatures
    widths = list(range(5, 17))
    sigs = serve_signatures(widths, max_new=16, n_slots=4)
    assert len(sigs) == 2 * len(widths) + 1
    findings = footprint_findings(sigs, budget=8)
    assert _errors(findings, "recompile-blowup")
    # the same workload chunked: prompts wider than the chunk all compile
    # to the (1, 8) chunk program -> {5,6,7,8}-wide chunks + decode
    sigs = serve_signatures(widths, max_new=16, n_slots=4, prefill_chunk=8)
    assert len(sigs) == 5
    assert not _errors(footprint_findings(sigs, budget=8))
    assert any(f.rule == "census" for f in findings)


def test_generate_signatures():
    sigs = generate_signatures(batch=4, prompt_width=16, max_new=10)
    assert [s.fn for s in sigs] == ["prefill", "decode"]
    assert sigs[0].static == (64,)                 # 64-rounded headroom
    assert sigs[1].shape == (4, 1)


def test_scheduler_compile_footprint(phi3, packed_params):
    api, _ = phi3
    eng = ServeEngine(api, packed_params, backend="pallas")
    req = Request(uid=0, inputs={"tokens": jnp.zeros((1, 7), jnp.int32)},
                  sampling=SamplingParams(max_new_tokens=8))
    sched = eng.make_scheduler([req], n_slots=2)
    sched.submit(req)
    sigs = sched.compile_footprint()
    assert any(s.fn == "decode" and s.shape == (2, 1) for s in sigs)
    assert any(s.shape[-1] == 7 for s in sigs if s.fn != "decode")


# ---------------------------------------------------------------------------
# sharding lint (satellite: structured fit_spec drops)
# ---------------------------------------------------------------------------

def test_fit_spec_records_and_warns_on_indivisible():
    # pad=False call sites (donated in-graph buffers) keep the drop path
    mesh = ShapeOnlyMesh({"data": 2, "model": 4})
    with collect_spec_events() as events:
        with pytest.warns(ShardingDropWarning, match="w7"):
            got = fit_spec(P("data", "model"), (7, 8), mesh, label="w7",
                           pad=False)
    assert got == P(None, "model")
    drops = [d for d in events if d.reason == "indivisible"]
    assert len(drops) == 1
    d = drops[0]
    assert (d.label, d.dim, d.axis) == ("w7", 0, "data")
    assert d.dim_size == 7 and d.axis_size == 2
    assert "w7" in d.message() and "data" in d.message()


def test_fit_spec_silent_drops_are_recorded_not_warned():
    mesh = ShapeOnlyMesh({"model": 4})
    with collect_spec_events() as events:
        with warnings.catch_warnings():
            warnings.simplefilter("error", ShardingDropWarning)
            got = fit_spec(P("data", "model"), (8, 8), mesh, label="w8")
    assert got == P(None, "model")
    assert any(d.reason == "absent" and d.axis == "data" for d in events)


def test_lint_sharding_production_mesh(phi3, packed_params):
    mesh = ShapeOnlyMesh(production_mesh_shape())
    assert mesh.shape == {"data": 16, "model": 16}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", ShardingDropWarning)
        findings = lint_sharding(packed_params, mesh)
    assert not _errors(findings)                   # pads degrade, not fail
    # the tiny config's dims are not 16-divisible: padded sharding keeps
    # them on the axis and surfaces each pad as an info finding
    assert any(f.rule == "axis-padded" for f in findings)
    assert not any(f.rule == "axis-indivisible" for f in findings)


def test_lint_sharding_clean_on_trivial_mesh(phi3, packed_params):
    findings = lint_sharding(packed_params,
                             ShapeOnlyMesh({"data": 1, "model": 1}))
    assert not _errors(findings)
    assert not any(f.rule == "mesh-axis-unused" for f in findings)


# ---------------------------------------------------------------------------
# HLO-text helpers
# ---------------------------------------------------------------------------

_HLO = """\
HloModule jit_decode, input_output_alias={ {0,1}: (2, {0}, may-alias) }

ENTRY main {
  %p0 = f32[4,8]{1,0} parameter(0)
  %c = s8[16,32]{1,0} convert(%p0)
  %d = f32[4,32]{1,0} dot(%p0, %c)
  ROOT %t = (f32[4,32]{1,0}) tuple(%d)
}
"""


def test_input_output_aliases_parse():
    aliases = input_output_aliases(_HLO)
    assert aliases == [((0, 1), 2, (0,))]
    assert input_output_aliases("HloModule nothing\n") == []


def test_shape_census():
    census = shape_census(_HLO)
    assert census["s8"] == 16 * 32
    assert census["f32"] == 4 * 8 * 4 + 4 * 32 * 4 * 2
    assert shape_census(_HLO, min_bytes=10 ** 6) == {}
