"""Request-level serving tests.

* continuous-batching parity: staggered requests through the scheduler are
  token-identical to one-shot ``generate`` for decoder-only, VLM, and
  enc-dec families (incl. quantized-at-rest caches and slot reuse);
* paged-cache parity: the block-table page pool (with slot reuse, chunked
  prefill, int8/int4 at-rest storage) reproduces the same tokens and
  drains without leaking pages, at lower resident bytes than fixed-width
  slots (randomized workloads: tests/test_serving_stress.py);
* KV bit-stability: a written slot's stored K/V never changes on later
  decode steps (the old engine re-quantized the whole cache every step);
* per-slot index vectors match the legacy scalar-index decode path;
* int4 odd-K deployment packing round-trips through serving_compose;
* sharded decode on a 2-device mesh matches single-device (subprocess:
  the test session is pinned to one CPU device), contiguous and paged.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models.api import build
from repro.models.common import QuantConfig, make_weight
from repro.serve import Request, SamplingParams, ServeEngine
from repro.serve.deploy import serving_compose, to_serving_params

KEY = jax.random.PRNGKey(3)


def _setup(arch, kv_bits=32, quant_mode="fake"):
    cfg = REGISTRY[arch].tiny(dtype="float32").with_quant(
        QuantConfig(mode=quant_mode, n_bits=8, act_bits=8))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, ServeEngine(api, params, kv_quant_bits=kv_bits)


def _batch(cfg, b=4, p=8):
    batch = {"tokens": jax.random.randint(
        KEY, (b, p), 0, cfg.vocab).astype(jnp.int32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.fold_in(KEY, 1),
            (b, cfg.vision_tokens, cfg.d_model)) * 0.1
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(KEY, 1), (b, p, cfg.d_model)) * 0.1
    return batch


# ---------------------------------------------------------------------------
# continuous batching == one-shot
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,kv_bits", [
    ("phi3-mini-3.8b", 32), ("phi3-mini-3.8b", 8), ("phi3-mini-3.8b", 4),
    ("qwen2-vl-2b", 32), ("qwen2-vl-2b", 8),
    ("seamless-m4t-large-v2", 32), ("seamless-m4t-large-v2", 8),
    ("granite-moe-3b-a800m", 32),   # exact 'ragged' dispatch (default)
])
def test_staggered_requests_match_oneshot(arch, kv_bits):
    """Requests arriving mid-decode (with slot reuse: 3 slots, 4 requests)
    must reproduce the static-batch greedy tokens exactly."""
    cfg, eng = _setup(arch, kv_bits)
    b, max_new = 4, 6
    batch = _batch(cfg, b=b)
    oneshot = np.asarray(eng.generate(batch, max_new=max_new))
    reqs = [Request(uid=i,
                    inputs={k: v[i:i + 1] for k, v in batch.items()},
                    sampling=SamplingParams(max_new_tokens=max_new),
                    arrival=2 * i)
            for i in range(b)]
    results = eng.serve(reqs, n_slots=3)
    for i, r in enumerate(results):
        assert r.tokens == oneshot[i].tolist(), f"slot-parity broke @req {i}"
        assert r.finish_reason == "length"
        assert r.admitted_tick >= reqs[i].arrival


@pytest.mark.parametrize("arch,kv_bits,page,chunk", [
    ("phi3-mini-3.8b", 8, 4, 0),
    ("phi3-mini-3.8b", 4, 4, 3),
    ("seamless-m4t-large-v2", 8, 4, 3),
    ("seamless-m4t-large-v2", 4, 4, 0),
    ("granite-moe-3b-a800m", 8, 4, 0),
    ("granite-moe-3b-a800m", 4, 4, 3),
    ("qwen2-vl-2b", 8, 4, 3),
    ("zamba2-1.2b", 8, 4, 0),     # hybrid: paged attn + recurrent rows
])
def test_paged_staggered_requests_match_oneshot(arch, kv_bits, page, chunk):
    """The paged cache (block tables over a shared page pool, slot reuse,
    optional chunked prefill) must reproduce one-shot greedy tokens
    exactly, and drain with every page back on the free list."""
    cfg, eng = _setup(arch, kv_bits)
    b, max_new = 4, 6
    batch = _batch(cfg, b=b)
    oneshot = np.asarray(eng.generate(batch, max_new=max_new))
    reqs = [Request(uid=i,
                    inputs={k: v[i:i + 1] for k, v in batch.items()},
                    sampling=SamplingParams(max_new_tokens=max_new),
                    arrival=2 * i)
            for i in range(b)]
    sched = eng.make_scheduler(reqs, n_slots=3, page_size=page,
                               prefill_chunk=chunk)
    results = sched.run(reqs)
    for i, r in enumerate(results):
        assert r.tokens == oneshot[i].tolist(), f"paged parity @req {i}"
    report = sched.cache_report()
    assert report["pages_in_use"] == 0, f"leaked pages: {report}"
    assert report["peak_pages_in_use"] > 0
    assert (sched.tables == 0).all()


def test_paged_resident_bytes_below_fixed_width():
    """Mixed-length requests: the paged pool's peak resident bytes must
    undercut the fixed-width layout's always-resident rows."""
    cfg, eng = _setup("phi3-mini-3.8b", 8)
    reqs = []
    for i, (pl, mn) in enumerate([(2, 2), (8, 4), (16, 4), (4, 2)]):
        toks = jax.random.randint(jax.random.fold_in(KEY, 10 + i),
                                  (1, pl), 0, cfg.vocab).astype(jnp.int32)
        reqs.append(Request(uid=i, inputs={"tokens": toks},
                            sampling=SamplingParams(max_new_tokens=mn),
                            arrival=i))
    paged = eng.make_scheduler(reqs, n_slots=4, max_len=64, page_size=4)
    res_p = paged.run(list(reqs))
    fixed = eng.make_scheduler(reqs, n_slots=4, max_len=64, page_size=0)
    res_f = fixed.run(list(reqs))
    assert all(a.tokens == b.tokens for a, b in zip(res_p, res_f))
    rp, rf = paged.cache_report(), fixed.cache_report()
    assert rp["bytes_in_use_peak"] < rf["resident_bytes"], (rp, rf)


def test_eos_retirement_frees_slot():
    """A request retiring on EOS frees its slot for a waiting request."""
    cfg, eng = _setup("phi3-mini-3.8b")
    batch = _batch(cfg, b=3)
    oneshot = np.asarray(eng.generate(batch, max_new=8))
    eos = int(oneshot[0, 2])                    # force an early stop on req 0
    reqs = [Request(uid=i, inputs={"tokens": batch["tokens"][i:i + 1]},
                    sampling=SamplingParams(
                        max_new_tokens=8, eos_id=eos if i == 0 else None),
                    arrival=i)
            for i in range(3)]
    results = eng.serve(reqs, n_slots=1)        # single slot: strict reuse
    assert results[0].finish_reason == "stop"
    assert results[0].tokens == oneshot[0, :3].tolist()
    for i in (1, 2):
        assert results[i].tokens == oneshot[i].tolist()
        assert results[i].finish_reason == "length"


def test_sampling_reproducible_and_respects_top_k():
    cfg, eng = _setup("phi3-mini-3.8b")
    batch = _batch(cfg, b=2)
    sp = SamplingParams(max_new_tokens=6, temperature=0.7, top_k=5, seed=11)
    reqs = [Request(uid=i, inputs={"tokens": batch["tokens"][i:i + 1]},
                    sampling=sp) for i in range(2)]
    r1 = eng.serve(list(reqs), n_slots=2)
    r2 = eng.serve(list(reqs), n_slots=2)
    for a, b_ in zip(r1, r2):
        assert a.tokens == b_.tokens            # per-request seeded PRNG
    greedy = eng.serve(
        [Request(uid=0, inputs={"tokens": batch["tokens"][:1]},
                 sampling=SamplingParams(max_new_tokens=6))], n_slots=1)
    assert len(r1[0].tokens) == len(greedy[0].tokens) == 6


def test_top_k_keeps_exactly_k_on_tied_logits():
    """Regression: the old ``l < kth`` threshold mask kept EVERY logit
    tied with the k-th value, so a plateau of equal logits widened the
    filter past top_k.  The rank mask must keep exactly k candidates,
    breaking ties by token id."""
    from repro.serve.sampling import sample_token
    v = 12
    # logits [9, 9, 9, 9, 8, 8, 8, 0, ...]: with k=2 the old mask kept 4
    # (tiers one logit apart so every survivor is drawn with probability
    # >= ~8% — 400 seeds cover the full surviving set with margin)
    logits = jnp.asarray([9., 9., 9., 9., 8., 8., 8.] + [0.] * (v - 7))
    sp = SamplingParams(temperature=1.0, top_k=2)
    hits = set()
    for s in range(200):
        hits.add(int(sample_token(logits, sp, jax.random.PRNGKey(s))))
    assert hits == {0, 1}, f"tied logits leaked past top_k: {hits}"
    # plateau straddling the cut: k=5 must stop inside the 8s, by token id
    sp5 = SamplingParams(temperature=1.0, top_k=5)
    hits5 = set()
    for s in range(400):
        hits5.add(int(sample_token(logits, sp5, jax.random.PRNGKey(s))))
    assert hits5 == {0, 1, 2, 3, 4}, hits5
    # untied logits: unchanged behavior (the k best survive)
    distinct = jnp.asarray([float(i) for i in range(v)])
    hits_d = set()
    for s in range(400):
        hits_d.add(int(sample_token(distinct, sp5, jax.random.PRNGKey(s))))
    assert hits_d <= {v - 1, v - 2, v - 3, v - 4, v - 5}, hits_d


# ---------------------------------------------------------------------------
# quantized-at-rest cache
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_bits", [8, 4])
def test_kv_cache_slots_bit_stable_across_decode(kv_bits):
    """Regression for the old ``_maybe_quant_cache``: stored K/V (and
    scales) of already-written positions must be bit-identical after any
    number of subsequent decode steps — each slot is quantized once."""
    cfg, eng = _setup("phi3-mini-3.8b", kv_bits)
    p = 8
    batch = _batch(cfg, b=2, p=p)
    logits, state = eng.prefill(batch, extra_slots=8)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

    def written(s, upto):
        c = s["cache"]
        return {k: np.asarray(c[k][:, :, :upto]).copy()
                for k in ("k", "v", "k_scale", "v_scale")}

    snap = written(state, p)
    assert state["cache"]["k"].dtype == (jnp.int8 if kv_bits == 8
                                         else jnp.uint8)
    for i in range(4):
        logits, state = eng.decode(tok, state,
                                   jnp.full((2,), p + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        assert np.isfinite(np.asarray(logits)).all()
        after = written(state, p)
        for name, ref in snap.items():
            np.testing.assert_array_equal(
                after[name], ref,
                err_msg=f"{name} re-quantized at decode step {i}")


def test_int8_kv_close_to_float_greedy():
    cfg, eng32 = _setup("phi3-mini-3.8b", 32)
    _, eng8 = _setup("phi3-mini-3.8b", 8)
    batch = _batch(cfg, b=2)
    out32 = np.asarray(eng32.generate(batch, max_new=8))
    out8 = np.asarray(eng8.generate(batch, max_new=8))
    assert (out32 == out8).mean() > 0.7


# ---------------------------------------------------------------------------
# per-slot index vector vs legacy scalar index
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "qwen2-vl-2b",
                                  "seamless-m4t-large-v2", "zamba2-1.2b"])
def test_vector_index_matches_scalar_decode(arch):
    cfg, eng = _setup(arch)
    api = eng.api
    p, b = 8, 2
    batch = _batch(cfg, b=b, p=p)
    tv = cfg.vision_tokens if cfg.family == "vlm" else 0
    logits, st_s = api.prefill(eng.params, batch, extra_slots=8)
    st_v = jax.tree_util.tree_map(lambda x: x, st_s)
    tok_s = tok_v = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(3):
        lg_s, st_s = api.decode_step(eng.params, tok_s, st_s,
                                     jnp.asarray(p + tv + i, jnp.int32))
        lg_v, st_v = api.decode_step(eng.params, tok_v, st_v,
                                     jnp.full((b,), p + tv + i, jnp.int32))
        np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_v))
        tok_s = jnp.argmax(lg_s, -1)[:, None].astype(jnp.int32)
        tok_v = jnp.argmax(lg_v, -1)[:, None].astype(jnp.int32)


# ---------------------------------------------------------------------------
# deployment packing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["fake", "bitplane"])
@pytest.mark.parametrize("k", [9, 16])
def test_int4_pack_roundtrip_odd_and_even_k(mode, k):
    """Nibble packing must handle odd block-padded K (regression: the old
    interleave silently dropped the unpaired row) and round-trip through
    serving_compose to the int8 path's values within int4 rescale error."""
    qc = QuantConfig(mode=mode, n_bits=8, wb_rows=3, wb_cols=8)
    w = make_weight(jax.random.PRNGKey(0), (k, 24), qc)
    sw8 = to_serving_params({"w": w}, bits=8)["w"]
    sw4 = to_serving_params({"w": w}, bits=4)["w"]
    kp = -(-k // 3) * 3                         # block-padded K (wb_rows=3)
    assert sw4.w_int.shape[-2] == (kp + 1) // 2
    w8 = np.asarray(serving_compose(sw8, jnp.float32))
    w4 = np.asarray(serving_compose(sw4, jnp.float32))
    assert w8.shape == w4.shape == (k, 24)
    scale = np.abs(w8).max() + 1e-9
    assert np.abs(w8 - w4).max() / scale < 0.25


# ---------------------------------------------------------------------------
# sharded serving (2 host devices, subprocess)
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import REGISTRY
from repro.models.api import build
from repro.models.common import QuantConfig
from repro.dist.sharding import use_mesh
from repro.launch.mesh import make_mesh
from repro.serve import ServeEngine, Request, SamplingParams

assert jax.device_count() == 2, jax.device_count()
cfg = REGISTRY["phi3-mini-3.8b"].tiny(dtype="float32").with_quant(
    QuantConfig(mode="fake", n_bits=8, act_bits=8))
api = build(cfg)
params = api.init(jax.random.PRNGKey(0))
batch = {"tokens": jax.random.randint(
    jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab).astype(jnp.int32)}
ref = np.asarray(ServeEngine(api, params, kv_quant_bits=8)
                 .generate(batch, max_new=6))
for shape in [(2, 1), (1, 2)]:
    with use_mesh(make_mesh(shape, ("data", "model"))):
        eng = ServeEngine(api, params, kv_quant_bits=8)
        out = np.asarray(eng.generate(batch, max_new=6))
        res = eng.serve(
            [Request(uid=i, inputs={"tokens": batch["tokens"][i:i+1]},
                     sampling=SamplingParams(max_new_tokens=6), arrival=i)
             for i in range(4)], n_slots=4)
    assert (out == ref).all(), shape
    assert all(res[i].tokens == ref[i].tolist() for i in range(4)), shape
print("SHARDED_OK")

# paged cache placed via cache_pspecs (page pool on the data axes, KV
# heads on the model axis, block tables replicated) must decode
# token-identically to single-device, int8 and int4 at-rest
for kv_bits in (8, 4):
    ref_res = ServeEngine(api, params, kv_quant_bits=kv_bits).serve(
        [Request(uid=i, inputs={"tokens": batch["tokens"][i:i+1]},
                 sampling=SamplingParams(max_new_tokens=6), arrival=i)
         for i in range(4)], n_slots=3, page_size=4, prefill_chunk=4)
    for shape in [(2, 1), (1, 2)]:
        with use_mesh(make_mesh(shape, ("data", "model"))):
            eng = ServeEngine(api, params, kv_quant_bits=kv_bits)
            res = eng.serve(
                [Request(uid=i, inputs={"tokens": batch["tokens"][i:i+1]},
                         sampling=SamplingParams(max_new_tokens=6),
                         arrival=i)
                 for i in range(4)], n_slots=3, page_size=4,
                prefill_chunk=4)
        assert all(res[i].tokens == ref_res[i].tokens for i in range(4)), (
            kv_bits, shape)
print("SHARDED_PAGED_OK")
"""


def test_sharded_decode_matches_single_device():
    """Data- and model-sharded 2-device serving must emit the exact tokens
    of the single-device engine (generate + scheduler + paged paths)."""
    env = dict(os.environ,
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")] +
                   sys.path))
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED_OK" in out.stdout
    assert "SHARDED_PAGED_OK" in out.stdout
