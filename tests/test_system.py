"""End-to-end system test: BWQ-A QAT -> compression -> deployment packing
-> serving, the full pipeline the paper describes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY
from repro.core import bitwidths
from repro.core.state import quantized_leaves
from repro.data import make_lm_pipeline
from repro.hw import (bwq_scheme, isaac_scheme, speedup_and_energy_saving,
                      workloads_from_params)
from repro.models.api import build
from repro.models.common import QuantConfig
from repro.optim import adamw, cosine_schedule
from repro.serve import ServeEngine
from repro.train import Trainer, TrainerConfig
from repro.train.step import quant_stats


def test_end_to_end_bwq_pipeline():
    """Train w/ BWQ-A on synthetic LM data, verify: CE improves, blocks get
    mixed precisions, HW sim shows speedup+energy saving over ISAAC, and the
    compressed model still serves coherent greedy decodes."""
    cfg = REGISTRY["phi3-mini-3.8b"].tiny(dtype="float32").with_quant(
        QuantConfig(mode="bitplane", n_bits=8, act_bits=8,
                    wb_rows=9, wb_cols=8))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))

    steps = 80
    tr = Trainer(lambda p, b: api.loss(p, b), adamw(weight_decay=0.0),
                 cosine_schedule(2e-3, steps), params,
                 TrainerConfig(total_steps=steps, ckpt_every=0,
                               ckpt_dir=None, log_every=20,
                               requant_interval=20, alpha_round_steps=20,
                               delta_alpha=1e-3))
    data = make_lm_pipeline(cfg, seq_len=32, batch=8)
    tr.run(data, steps=steps)

    # 1) learning happened
    assert tr.history[-1]["ce"] < tr.history[0]["ce"]

    # 2) block-wise mixed precision emerged (not all blocks at 8 bits)
    stats = quant_stats(tr.state.params)
    assert float(stats["avg_bitwidth"]) < 8.0
    some_mixed = False
    for qt in quantized_leaves(tr.state.params).values():
        bw = np.asarray(bitwidths(qt))
        if len(np.unique(bw)) > 1:
            some_mixed = True
    assert some_mixed, "expected block-wise (not uniform) precision"

    # 3) hardware win over ISAAC from the learned bit-width tables
    wls = workloads_from_params(tr.state.params, positions=16, act_bits=8)
    sp, en = speedup_and_energy_saving(wls, bwq_scheme(), isaac_scheme())
    assert sp > 1.5 and en > 1.5

    # 4) the quantized model serves
    eng = ServeEngine(api, tr.state.params)
    out = eng.generate({"tokens": jnp.ones((2, 8), jnp.int32)}, max_new=4)
    assert out.shape == (2, 4)
    assert np.isfinite(np.asarray(out)).all()
