import os

# Single-device CPU world for tests; the dry-run (and only the dry-run)
# forces 512 host devices via its own module-level XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
