"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU; the kernels target TPU BlockSpec tiling)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BlockingSpec, adjust_precision, compose, from_float,
                        requantize)
from repro.kernels import (bitplane_matmul, bwq_dense_bitplane,
                           bwq_dense_packed, pact_quant_pallas, to_bitplane_layout,
                           to_packed_layout)
from repro.kernels.ref import (bitplane_matmul_ref, packed_matmul_ref,
                               pact_quant_ref)

KEY = jax.random.PRNGKey(42)
SPEC = BlockingSpec(8, 128)


def make_qt(k, n, n_bits=8, prune_frac=0.5, seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * 0.05
    qt = requantize(from_float(w, n_bits, SPEC))
    # prune the top planes of a contiguous region to create mixed precision
    cut = int(n * prune_frac) // 128 * 128
    if cut:
        planes = qt.planes.at[n_bits // 2:, :, :cut].set(0.0)
        qt = requantize(adjust_precision(
            dataclasses.replace(qt, planes=planes)))
    return qt


@pytest.mark.parametrize("m,k,n", [(16, 128, 128), (64, 256, 256),
                                   (128, 512, 384), (32, 1024, 128)])
def test_bitplane_matmul_shapes(m, k, n):
    qt = make_qt(k, n)
    x = jax.random.normal(KEY, (m, k))
    bl = to_bitplane_layout(qt)
    y_ref = bitplane_matmul_ref(x, bl.planes_packed, bl.sign_packed,
                                bl.mask, bl.scale[0], 8, 128)
    y = bwq_dense_bitplane(x, bl)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    # and the whole pipeline against the composed weight
    y_true = x @ compose(qt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_true),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bitplane_matmul_dtypes(dtype):
    qt = make_qt(256, 256)
    x = jax.random.normal(KEY, (32, 256)).astype(dtype)
    bl = to_bitplane_layout(qt)
    y = bwq_dense_bitplane(x, bl)
    y_ref = bitplane_matmul_ref(x, bl.planes_packed, bl.sign_packed,
                                bl.mask, bl.scale[0], 8, 128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("m,k,n", [(16, 128, 128), (64, 512, 256)])
def test_packed_matmul_vs_ref(bits, m, k, n):
    qt = make_qt(k, n, seed=bits)
    x = jax.random.normal(KEY, (m, k))
    pk = to_packed_layout(qt, bits)
    y = bwq_dense_packed(x, pk)
    y_ref = packed_matmul_ref(x, pk.w_int, pk.scale, bits)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_packed8_accuracy_vs_true():
    """int8 path drops at most 1 LSB on full-precision blocks."""
    qt = make_qt(512, 256)
    x = jax.random.normal(KEY, (64, 512))
    y_true = x @ compose(qt)
    y = bwq_dense_packed(x, to_packed_layout(qt, 8))
    rel = float(jnp.max(jnp.abs(y - y_true)) / jnp.max(jnp.abs(y_true)))
    assert rel < 0.05


def test_packed4_lossless_on_low_precision_blocks():
    """Blocks already at <=3 bits are exact in the int4 container."""
    w = jax.random.normal(KEY, (128, 128)) * 0.05
    qt = requantize(from_float(w, 8, SPEC))
    planes = qt.planes.at[3:].set(0.0)       # force <=3 magnitude bits
    qt = requantize(adjust_precision(dataclasses.replace(qt, planes=planes)))
    x = jax.random.normal(KEY, (16, 128))
    y_true = x @ compose(qt)
    y = bwq_dense_packed(x, to_packed_layout(qt, 4))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_true),
                               rtol=1e-5, atol=1e-5)


def test_masked_planes_are_skipped():
    """Masked-out planes contribute nothing (OU-skip semantics)."""
    qt = make_qt(256, 128, prune_frac=1.0)
    x = jax.random.normal(KEY, (8, 256))
    bl = to_bitplane_layout(qt)
    y = bwq_dense_bitplane(x, bl)
    y_true = x @ compose(qt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_true),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("act_bits", [2, 4, 8])
@pytest.mark.parametrize("shape", [(256, 64), (512, 128)])
def test_pact_kernel(act_bits, shape):
    x = jax.random.normal(KEY, shape)
    y = pact_quant_pallas(x, jnp.asarray([1.3]), act_bits=act_bits)
    y_ref = pact_quant_ref(x, jnp.asarray(1.3), act_bits)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-6)


def test_block_grid_tiling_variants():
    """Different BlockSpec tilings give identical results."""
    qt = make_qt(1024, 256)
    x = jax.random.normal(KEY, (64, 1024))
    bl = to_bitplane_layout(qt)
    y1 = bitplane_matmul(x, bl.planes_packed, bl.sign_packed, bl.mask,
                         bl.scale, block_m=64, block_n=128, block_k=256)
    y2 = bitplane_matmul(x, bl.planes_packed, bl.sign_packed, bl.mask,
                         bl.scale, block_m=32, block_n=256, block_k=512)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
