"""Sharding-rule unit tests (1-device mesh: axes exist, sizes are 1)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import REGISTRY
from repro.dist.sharding import (batch_pspecs, cache_pspecs, fit_spec,
                                 param_pspecs, use_mesh)
from repro.launch.mesh import make_mesh
from repro.models.api import build
from repro.models.common import QuantConfig


@pytest.fixture
def mesh1():
    return make_mesh((1, 1), ("data", "model"))


def _find(specs, params, needle):
    flat_s = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    return {jax.tree_util.keystr(p): s for p, s in flat_s
            if needle in jax.tree_util.keystr(p)}


def test_param_rules_dense(mesh1):
    cfg = REGISTRY["phi3-mini-3.8b"].tiny().with_quant(
        QuantConfig(mode="fake", n_bits=8, wb_rows=8, wb_cols=8))
    api = build(cfg)
    params = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    with use_mesh(mesh1):
        specs = param_pspecs(params)
    wq = _find(specs, params, "wq")
    assert any("model" in str(s) for s in wq.values())
    wo = list(_find(specs, params, "['attn']['wo'].w").values())[0]
    assert wo[-2] == "model"                      # row-parallel
    # quant metadata scale replicated
    sc = list(_find(specs, params, "wq.scale").values())
    assert all(s == P() for s in sc)


def test_fsdp_on_big_weights(mesh1):
    """Big weights get their free dim data-sharded (ZeRO-3)."""
    from repro.dist.sharding import _leaf_spec
    with use_mesh(mesh1):
        ps = _leaf_spec("['layers']['attn']['wo'].w",
                        jax.ShapeDtypeStruct((2048, 1024), jnp.float32))
        assert ps == P("model", "data")
        ps_small = _leaf_spec("['layers']['attn']['wo'].w",
                              jax.ShapeDtypeStruct((64, 64), jnp.float32))
        assert "data" not in str(ps_small)
        # router excluded from FSDP
        ps_r = _leaf_spec("['moe']['router_w']",
                          jax.ShapeDtypeStruct((4096, 512), jnp.float32))
        assert ps_r == P(None, None)


def test_expert_and_router_rules(mesh1):
    cfg = REGISTRY["granite-moe-3b-a800m"].tiny().with_quant(
        QuantConfig(mode="none"))
    api = build(cfg)
    params = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    with use_mesh(mesh1):
        specs = param_pspecs(params)
    gate = list(_find(specs, params, "expert_gate").values())[0]
    assert gate[-1] == "model"
    router = list(_find(specs, params, "router_w").values())[0]
    assert "model" not in str(router)             # router replicated


def test_fit_spec_divisibility():
    mesh = make_mesh((1, 1), ("data", "model"))
    ps = fit_spec(P("data", "model"), (7, 8), mesh)
    assert ps == P("data", "model")               # axis size 1 divides all


def test_batch_and_cache_pspecs(mesh1):
    with use_mesh(mesh1):
        b = batch_pspecs({"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)})
        assert b["tokens"][0] is not None
        cache = {"cache": {
            "k": jax.ShapeDtypeStruct((4, 8, 128, 2, 16), jnp.float32)}}
        cs = cache_pspecs(cache, batch_size=8)
        assert cs["cache"]["k"][3] == "model"     # kv heads on model


def test_no_mesh_is_noop():
    cfg = REGISTRY["phi3-mini-3.8b"].tiny()
    api = build(cfg)
    params = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    specs = param_pspecs(params)
    assert all(s == P() for s in jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))


def test_multipod_fit_and_cache_pspecs():
    """3-axis (pod, data, model) mesh: batch spans (pod, data); non-dividing
    dims drop their mesh axes instead of failing."""
    from repro.dist.sharding import spec
    mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
    with use_mesh(mesh):
        ps = spec("batch", None, "ff")
        assert ps[0] == ("pod", "data")
        assert ps[2] == "model"
        # 7 is not divisible by a >1 axis; with size-1 axes everything fits
        assert fit_spec(P(("pod", "data"), "model"), (7, 8), mesh) == \
            P(("pod", "data"), "model")
        cache = {"cache": {
            "k": jax.ShapeDtypeStruct((4, 16, 128, 2, 64), jnp.float32),
            "k_scale": jax.ShapeDtypeStruct((4, 16, 128, 2), jnp.float32)}}
        cs = cache_pspecs(cache, batch_size=16)
        assert cs["cache"]["k"][1] == ("pod", "data")
        assert cs["cache"]["k"][3] == "model"
        assert cs["cache"]["k_scale"][1] == ("pod", "data")
        # n_layers == batch_size: the leading stacked-layer dim must not
        # steal the batch sharding
        cs2 = cache_pspecs(
            {"k": jax.ShapeDtypeStruct((16, 16, 64, 2, 8), jnp.float32)},
            batch_size=16)
        assert cs2["k"][0] is None and cs2["k"][1] == ("pod", "data")
    # a >1 mesh axis that does NOT divide the dim: padded sharding (the
    # default) keeps it — the placement boundary zero-pads the dim — and
    # pad=False restores the legacy drop; fit_spec only reads mesh.shape,
    # so a stand-in covers >1 sizes on 1 device
    class _Mesh22:
        shape = {"data": 2, "model": 2}

    mesh2 = _Mesh22()
    assert fit_spec(P("data", "model"), (7, 8), mesh2) == P("data", "model")
    assert fit_spec(P("data", "model"), (7, 8), mesh2, pad=False) == \
        P(None, "model")
    assert fit_spec(P("data", "model"), (8, 7), mesh2, pad=False) == \
        P("data", None)
    # axes absent from the mesh are dropped regardless of padding
    assert fit_spec(P(("pod", "data"), None), (8, 8), mesh2) == \
        P("data", None)


def test_padded_fit_spec_and_helpers():
    """Ceil-division padded sharding: spec kept, SpecPad recorded, the
    pad/unpad boundary helpers round-trip exactly."""
    import numpy as np
    from repro.dist.sharding import (SpecPad, collect_spec_events, pad_leaf,
                                     padded_shape, unpad_leaf)

    class _Mesh22:
        shape = {"data": 2, "model": 2}

    mesh = _Mesh22()
    with collect_spec_events() as events:
        ps = fit_spec(P("data", "model"), (7, 8), mesh, label="x")
    assert ps == P("data", "model")
    pads = [e for e in events if isinstance(e, SpecPad)]
    assert len(pads) == 1 and pads[0].dim == 0 \
        and pads[0].padded_size == 8 and pads[0].group_size == 2
    assert padded_shape(ps, (7, 8), mesh) == (8, 8)
    x = np.arange(7 * 8, dtype=np.float32).reshape(7, 8)
    xp = pad_leaf(x, ps, mesh)
    assert xp.shape == (8, 8) and not xp[7].any()
    np.testing.assert_array_equal(unpad_leaf(xp, (7, 8)), x)
    # in-graph / donated call sites opt out and keep the legacy drop
    assert fit_spec(P("data", "model"), (7, 8), mesh,
                    pad=False) == P(None, "model")


def test_hlo_mixed_dtypes_and_no_collectives():
    from repro.dist.hlo_analysis import collective_stats
    txt = """
  %ar0 = f32[128,16]{1,0} all-reduce(%a), channel_id=1
  %ar1 = bf16[64]{0} all-reduce(%b), channel_id=2
  %rs = s8[256,4]{1,0} reduce-scatter(%c), dimensions={0}
  %ag-start = (f32[32], f32[256]) all-gather-start(%d), dimensions={0}
  %ag-done = f32[256]{0} all-gather-done(%ag-start)
  ROOT %r = f32[8]{0} add(%x, %y)
"""
    st = collective_stats(txt)
    assert st.counts == {"all-reduce": 2, "reduce-scatter": 1,
                         "all-gather": 1}
    assert st.bytes_by_op["all-reduce"] == 128 * 16 * 4 + 64 * 2
    assert st.bytes_by_op["reduce-scatter"] == 256 * 4 * 1
    assert st.bytes_by_op["all-gather"] == 256 * 4   # start skipped
    assert st.total_bytes == sum(st.bytes_by_op.values())
    # collective-free HLO (pure compute) -> empty stats
    empty = collective_stats("  ROOT %m = f32[64,64]{1,0} dot(%a, %b)")
    assert empty.counts == {} and empty.total_bytes == 0


def test_hlo_collective_parser():
    from repro.dist.hlo_analysis import collective_stats
    txt = """
  %all-reduce.1 = f32[256,512]{1,0} all-reduce(%dot), channel_id=1
  %ag = bf16[1024,64]{1,0} all-gather(%p0), dimensions={0}
  ROOT %x = f32[8]{0} add(%a, %b)
"""
    st = collective_stats(txt)
    assert st.counts == {"all-reduce": 1, "all-gather": 1}
    assert st.bytes_by_op["all-reduce"] == 256 * 512 * 4
    assert st.bytes_by_op["all-gather"] == 1024 * 64 * 2
