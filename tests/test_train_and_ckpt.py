"""Training-loop integration: QAT compression progress, checkpoint/restart
fault tolerance, deterministic data, optimizers, gradient compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager, restore_tree, save_tree
from repro.configs import REGISTRY
from repro.data import SyntheticCIFAR, SyntheticLM, make_lm_pipeline
from repro.models.api import build
from repro.models.common import QuantConfig
from repro.optim import (adamw, compress_decompress, cosine_schedule,
                         init_error_state, sgd)
from repro.train import Trainer, TrainerConfig
from repro.train.loop import run_with_restarts


def _setup(mode="bitplane", act_bits=8):
    cfg = REGISTRY["phi3-mini-3.8b"].tiny(dtype="float32")
    cfg = cfg.with_quant(QuantConfig(mode=mode, n_bits=8, act_bits=act_bits))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


class TestTrainLoop:
    def test_loss_decreases_and_quant_progresses(self):
        cfg, api, params = _setup()
        tr = Trainer(lambda p, b: api.loss(p, b), adamw(weight_decay=0.0),
                     cosine_schedule(2e-3, 40), params,
                     TrainerConfig(total_steps=40, ckpt_every=0,
                                   ckpt_dir=None, log_every=5,
                                   requant_interval=10,
                                   alpha_round_steps=10, delta_alpha=3e-4))
        data = make_lm_pipeline(cfg, seq_len=32, batch=8)
        tr.run(data, steps=40)
        first, last = tr.history[0], tr.history[-1]
        assert last["ce"] < first["ce"]
        # group lasso + precision adjustment must have started compressing
        assert last["avg_bitwidth"] <= 8.0
        assert last["compression_x"] >= 4.0

    def test_fault_injection_and_restart(self):
        cfg, api, params = _setup(mode="fake")
        with tempfile.TemporaryDirectory() as d:
            def make_trainer():
                return Trainer(lambda p, b: api.loss(p, b),
                               sgd(momentum=0.9, weight_decay=0.0),
                               cosine_schedule(1e-2, 30), params,
                               TrainerConfig(total_steps=30, ckpt_every=10,
                                             ckpt_dir=d, log_every=10,
                                             requant_interval=0))

            def make_data(start):
                return make_lm_pipeline(cfg, 32, 8, start_step=start)

            tr = run_with_restarts(make_trainer, make_data, total_steps=30,
                                   fault_at=15)
            assert int(tr.state.step) == 30
            # restart resumed from the step-10 checkpoint, not from scratch
            assert tr.try_restore() == 30


class TestCheckpoint:
    def test_roundtrip_with_quantized_leaves(self):
        cfg, api, params = _setup()
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "ck")
            save_tree(params, path)
            template = jax.tree_util.tree_map(jnp.zeros_like, params)
            restored = restore_tree(template, path)
            for a, b in zip(jax.tree_util.tree_leaves(params),
                            jax.tree_util.tree_leaves(restored)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_atomic_no_tmp_left_and_gc(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2, use_async=False)
            tree = {"a": jnp.arange(5.0)}
            for step in (1, 2, 3, 4):
                mgr.save(step, tree)
            assert mgr.latest_step() == 4
            dirs = sorted(os.listdir(d))
            assert dirs == ["step_3", "step_4"]
            assert not any(x.endswith(".tmp") for x in dirs)

    def test_restore_latest_with_meta(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2, use_async=True)
            tree = {"w": jnp.ones((3, 3))}
            mgr.save(7, tree, dict(step=7))
            mgr.wait()
            (step, extra), restored = mgr.restore_latest(
                jax.tree_util.tree_map(jnp.zeros_like, tree))
            assert step == 7 and extra["step"] == 7
            np.testing.assert_array_equal(np.asarray(restored["w"]), 1.0)


class TestData:
    def test_index_addressable_determinism(self):
        a = SyntheticLM(vocab=64, seq_len=16, batch=4, seed=3)
        b = SyntheticLM(vocab=64, seq_len=16, batch=4, seed=3)
        for step in (0, 5, 1000):
            np.testing.assert_array_equal(
                np.asarray(a.batch_at(step)["tokens"]),
                np.asarray(b.batch_at(step)["tokens"]))

    def test_labels_are_next_token(self):
        g = SyntheticLM(vocab=64, seq_len=16, batch=4, seed=0)
        b0 = g.batch_at(0)
        succ = g.succ
        tok = np.asarray(b0["tokens"])
        lab = np.asarray(b0["labels"])
        # every label is one of the planted successors of its token
        for i in range(4):
            for t in range(16):
                assert lab[i, t] in succ[tok[i, t]]

    def test_cifar_templates_learnable(self):
        g = SyntheticCIFAR(batch=16, noise=0.1)
        b = g.batch_at(0)
        assert b["images"].shape == (16, 32, 32, 3)
        # nearest-template classification should beat chance on low noise
        imgs = np.asarray(b["images"]).reshape(16, -1)
        tpl = g.templates.reshape(10, -1)
        pred = np.argmax(imgs @ tpl.T, axis=1)
        assert (pred == np.asarray(b["labels"])).mean() > 0.5


class TestOptim:
    def test_sgd_and_adamw_minimize_quadratic(self):
        target = jnp.asarray([1.0, -2.0, 3.0])

        def loss(p):
            return jnp.sum((p["x"] - target) ** 2)

        for opt, lr in [(sgd(momentum=0.9, weight_decay=0.0), 0.05),
                        (adamw(weight_decay=0.0), 0.2)]:
            params = {"x": jnp.zeros(3)}
            state = opt.init(params)
            for _ in range(100):
                g = jax.grad(loss)(params)
                params, state = opt.update(g, state, params, lr)
            assert float(loss(params)) < 1e-2

    def test_grad_compression_error_feedback(self):
        g = {"w": jnp.asarray([1e-3, 0.5, -0.25])}
        err = init_error_state(g)
        acc = jnp.zeros(3)
        for _ in range(64):
            deq, err = compress_decompress(g, err)
            acc = acc + deq["w"]
        # error feedback: long-run mean converges to the true gradient
        np.testing.assert_allclose(np.asarray(acc) / 64,
                                   np.asarray(g["w"]), rtol=0.05, atol=1e-4)


class TestServe:
    def test_generate_and_kv_quant(self):
        from repro.serve import ServeEngine
        cfg, api, params = _setup(mode="fake")
        eng = ServeEngine(api, params)
        batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
        out = eng.generate(batch, max_new=4)
        assert out.shape == (2, 4)
        eng8 = ServeEngine(api, params, kv_quant_bits=8)
        out8 = eng8.generate(batch, max_new=4)
        assert out8.shape == (2, 4)
        # int8 KV cache should not change greedy tokens at these scales
        assert (np.asarray(out) == np.asarray(out8)).mean() > 0.7
