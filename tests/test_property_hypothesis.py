"""Property-based tests: BWQ-A invariants and qmatmul backend parity.

Runs under `hypothesis` when installed; otherwise the deterministic
fallback driver (`repro.testing.proptest`) draws a bounded seeded case
set, so these properties are exercised in every environment instead of
silently skipping."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                        # optional dep: seeded fallback
    from repro.testing import proptest as _pt
    given, settings, st = _pt.given, _pt.settings, _pt

from repro.core import (BlockingSpec, adjust_precision, bitwidths, compose,
                        from_float, layer_bit_count, requantize)
from repro.core.blocking import block_elem_counts
from repro.core.fakequant import fq_from_float, fq_maintenance, fq_compose
from repro.kernels.ref import pack_bits, unpack_bits
from repro.models.common import QuantConfig, make_weight, qmatmul
from repro.serve.deploy import bitplane_stream_bytes, to_serving_params

# the whole module is randomized sweeps: full-tier / local-only
pytestmark = pytest.mark.slow

SETTINGS = dict(max_examples=20, deadline=None)


@st.composite
def weight_case(draw):
    k = draw(st.integers(5, 40))
    n = draw(st.integers(5, 40))
    n_bits = draw(st.sampled_from([2, 4, 8]))
    wbr = draw(st.sampled_from([3, 8, 9]))
    wbc = draw(st.sampled_from([4, 8]))
    seed = draw(st.integers(0, 2 ** 16))
    scale = draw(st.floats(1e-3, 10.0))
    return k, n, n_bits, wbr, wbc, seed, scale


@given(weight_case())
@settings(**SETTINGS)
def test_reconstruction_bound(case):
    """|compose(from_float(w)) - w| <= scale / (2^n - 1) / 2 elementwise."""
    k, n, n_bits, wbr, wbc, seed, scale = case
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * scale
    qt = from_float(w, n_bits, BlockingSpec(wbr, wbc))
    err = np.max(np.abs(np.asarray(compose(qt) - w)))
    bound = float(jnp.max(jnp.abs(w))) / (2 ** n_bits - 1) / 2
    assert err <= bound * (1 + 1e-5) + 1e-9


@given(weight_case())
@settings(**SETTINGS)
def test_precision_adjustment_monotone_and_prefix(case):
    k, n, n_bits, wbr, wbc, seed, scale = case
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * scale
    qt = requantize(from_float(w, n_bits, BlockingSpec(wbr, wbc)))
    qt1 = adjust_precision(qt)
    mask = np.asarray(qt1.mask)
    # prefix property: once a bit is off, all higher bits are off
    for b in range(1, n_bits):
        assert np.all(mask[b] <= mask[b - 1] + 1e-9)
    # monotone under repetition
    qt2 = adjust_precision(requantize(qt1))
    assert np.all(np.asarray(bitwidths(qt2)) <= np.asarray(bitwidths(qt1)))


@given(weight_case())
@settings(**SETTINGS)
def test_requantize_composes_exactly_representable(case):
    """After requantize, compose is on the exact scale grid."""
    k, n, n_bits, wbr, wbc, seed, scale = case
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * scale
    qt = requantize(from_float(w, n_bits, BlockingSpec(wbr, wbc)))
    wq = np.asarray(compose(qt), dtype=np.float64)
    s = float(qt.scale) / (2 ** n_bits - 1)
    q = wq / s
    assert np.max(np.abs(q - np.round(q))) < 1e-3


@given(weight_case())
@settings(**SETTINGS)
def test_live_bits_match_numpy_reference(case):
    k, n, n_bits, wbr, wbc, seed, scale = case
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * scale
    spec = BlockingSpec(wbr, wbc)
    qt = adjust_precision(requantize(from_float(w, n_bits, spec)))
    elems = np.asarray(block_elem_counts((k, n), spec))
    bw = np.asarray(bitwidths(qt))
    assert float(layer_bit_count(qt)) == float((bw * elems).sum())


@given(weight_case())
@settings(**SETTINGS)
def test_fakequant_tracks_bitplane(case):
    """fake-quant compose == bit-plane compose for exact-binary states."""
    k, n, n_bits, wbr, wbc, seed, scale = case
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * scale
    spec = BlockingSpec(wbr, wbc)
    qt = requantize(adjust_precision(requantize(from_float(w, n_bits, spec))))
    fq = fq_from_float(w, n_bits, spec)
    fq = dataclasses.replace(
        fq, bitwidth=jnp.sum(qt.mask, axis=0).astype(fq.bitwidth.dtype))
    fq = fq_maintenance(fq)
    np.testing.assert_allclose(np.asarray(fq_compose(fq)),
                               np.asarray(compose(qt)),
                               atol=float(qt.scale) * 1e-5 + 1e-6)


@given(st.integers(1, 8), st.integers(1, 6), st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_pack_unpack_bits_roundtrip(rows8, cols, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(rows8 * 8, cols)).astype(np.float32)
    packed = pack_bits(jnp.asarray(bits))
    out = np.asarray(unpack_bits(packed))
    np.testing.assert_array_equal(out, bits)


# ---------------------------------------------------------------------------
# qmatmul backend parity (pad-and-trim kernel paths)
# ---------------------------------------------------------------------------
#
# The PR 3 kernels pad non-tile-divisible M/K/N and trim the result; until
# now only hand-picked shapes were covered (tests/test_kernels.py).  These
# draw random matmul problems — decode-shaped M=1..16, ragged N, and K
# values whose block padding is odd under the paper's 9x8 WB geometry (the
# int4 nibble-pack must add a zero row) — and assert the dense in-graph
# dequant, the Pallas kernel (interpret mode on CPU), and the pure-jnp
# oracle agree on deployed packed weights.

@st.composite
def matmul_case(draw):
    m = draw(st.sampled_from([1, 2, 3, 5, 7, 8, 13, 16, 33, 64]))
    # 9-row WBs (paper geometry) make K=9/27/63 block-pad to odd rows
    wbr, wbc = draw(st.sampled_from([(9, 8), (3, 8), (8, 128)]))
    k = draw(st.sampled_from([9, 17, 27, 63, 64, 72, 128]))
    n = draw(st.sampled_from([8, 24, 56, 96, 128, 200]))
    bits = draw(st.sampled_from([8, 4]))
    seed = draw(st.integers(0, 2 ** 16))
    return m, k, n, bits, wbr, wbc, seed


@given(matmul_case())
@settings(max_examples=10, deadline=None)
def test_qmatmul_backend_parity_random_shapes(case):
    m, k, n, bits, wbr, wbc, seed = case
    qc = QuantConfig(mode="fake", n_bits=8, wb_rows=wbr, wb_cols=wbc)
    w = make_weight(jax.random.PRNGKey(seed), (k, n), qc)
    sw = to_serving_params({"w": w}, bits=bits)["w"]
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (m, k))
    y_dense = np.asarray(qmatmul(x, sw, backend="dense"))
    y_ref = np.asarray(qmatmul(x, sw, backend="ref"))
    y_pal = np.asarray(qmatmul(x, sw, backend="pallas"))
    assert y_dense.shape == y_ref.shape == y_pal.shape == (m, n)
    scale = np.abs(y_ref).max() + 1e-9
    np.testing.assert_allclose(y_pal / scale, y_ref / scale, atol=1e-5)
    np.testing.assert_allclose(y_dense / scale, y_ref / scale, atol=1e-4)


@given(matmul_case(), st.integers(1, 4))
@settings(max_examples=6, deadline=None)
def test_qmatmul_batched_inputs_match_flat(case, extra_dim):
    """qmatmul flattens leading dims before the kernel and restores them —
    a (B, S, K) activation must equal row-by-row 2-D calls."""
    m, k, n, bits, wbr, wbc, seed = case
    qc = QuantConfig(mode="fake", n_bits=8, wb_rows=wbr, wb_cols=wbc)
    w = make_weight(jax.random.PRNGKey(seed), (k, n), qc)
    sw = to_serving_params({"w": w}, bits=bits)["w"]
    x = jax.random.normal(jax.random.PRNGKey(seed + 2), (extra_dim, m, k))
    y = np.asarray(qmatmul(x, sw, backend="ref"))
    assert y.shape == (extra_dim, m, n)
    for b in range(extra_dim):
        yb = np.asarray(qmatmul(x[b], sw, backend="ref"))
        np.testing.assert_allclose(y[b], yb, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# bit-plane serving layout: bitplane_matmul vs its jnp oracle vs the dense
# compose, under random decode-shaped M, ragged N, odd block-padded K and
# *mixed per-block bit-widths* (the paper's whole point: each block's live
# bit count is what the kernel streams and what the bytes accounting bills)
# ---------------------------------------------------------------------------

def _mixed_fq(k, n, qc, seed):
    """FakeQuantTensor with a random per-WB bit-width assignment (0..8),
    snapped onto its grid by fq_maintenance."""
    fq = make_weight(jax.random.PRNGKey(seed), (k, n), qc)
    gr, gc = qc.spec.grid(k, n)
    bws = jax.random.randint(jax.random.PRNGKey(seed + 1), (gr, gc), 0, 9)
    fq = dataclasses.replace(fq, bitwidth=bws.astype(fq.bitwidth.dtype))
    return fq_maintenance(fq)


@st.composite
def bitplane_case(draw):
    m = draw(st.sampled_from([1, 2, 3, 5, 8, 13, 16, 33]))
    # 9x8 is the paper OU geometry; 9-row WBs block-pad K to odd rows
    wbr, wbc = draw(st.sampled_from([(9, 8), (3, 8), (8, 128)]))
    k = draw(st.sampled_from([9, 17, 27, 63, 64, 72, 128]))
    n = draw(st.sampled_from([8, 24, 56, 100, 128]))
    bits = draw(st.sampled_from([8, 4]))
    seed = draw(st.integers(0, 2 ** 16))
    return m, k, n, bits, wbr, wbc, seed


@given(bitplane_case())
@settings(max_examples=10, deadline=None)
def test_bitplane_backend_parity_mixed_bitwidths(case):
    """Pallas bitplane kernel == jnp oracle == dense compose on the
    plane-sliced serving weight, for mixed per-block bit-widths."""
    m, k, n, bits, wbr, wbc, seed = case
    qc = QuantConfig(mode="fake", n_bits=8, wb_rows=wbr, wb_cols=wbc)
    fq = _mixed_fq(k, n, qc, seed)
    bp = to_serving_params({"w": fq}, bits=bits, layout="bitplane")["w"]
    x = jax.random.normal(jax.random.PRNGKey(seed + 2), (m, k))
    y_dense = np.asarray(qmatmul(x, bp, backend="dense"))
    y_ref = np.asarray(qmatmul(x, bp, backend="ref"))
    y_bp = np.asarray(qmatmul(x, bp, backend="bitplane"))
    assert y_dense.shape == y_ref.shape == y_bp.shape == (m, n)
    scale = np.abs(y_ref).max() + 1e-9
    np.testing.assert_allclose(y_bp / scale, y_ref / scale, atol=1e-5)
    np.testing.assert_allclose(y_dense / scale, y_ref / scale, atol=1e-5)


@given(bitplane_case())
@settings(max_examples=10, deadline=None)
def test_bitplane_composes_identical_to_packed(case):
    """Cross-representation invariant: both serving layouts quantize
    through the same integer grid, so their dense composes — and hence
    dense-backend outputs — are BIT-IDENTICAL, and the bit-plane layout
    never streams more plane-bytes than the packed container would
    (min(bw, bits) + sign planes <= (bits+...) worth of payload for every
    mixed assignment; fully-masked blocks stream nothing)."""
    m, k, n, bits, wbr, wbc, seed = case
    qc = QuantConfig(mode="fake", n_bits=8, wb_rows=wbr, wb_cols=wbc)
    fq = _mixed_fq(k, n, qc, seed)
    bp = to_serving_params({"w": fq}, bits=bits, layout="bitplane")["w"]
    pk = to_serving_params({"w": fq}, bits=bits)["w"]
    x = jax.random.normal(jax.random.PRNGKey(seed + 2), (m, k))
    y_bp = np.asarray(qmatmul(x, bp, backend="dense"))
    y_pk = np.asarray(qmatmul(x, pk, backend="dense"))
    np.testing.assert_array_equal(y_bp, y_pk)
    # occupancy accounting: mask rows mirror min(bw, bits) exactly
    live = np.asarray(bp.mask).sum(axis=0)
    want = np.minimum(np.asarray(fq.bitwidth), bits)
    np.testing.assert_array_equal(live, want)
    assert bitplane_stream_bytes(bp) > 0


# ---------------------------------------------------------------------------
# fused paged-attention kernel vs jnp oracle (randomized shapes)
# ---------------------------------------------------------------------------

@st.composite
def paged_attn_case(draw):
    b = draw(st.integers(1, 3))
    kv = draw(st.sampled_from([1, 2, 4]))
    g = draw(st.sampled_from([1, 2, 4]))
    dh = draw(st.sampled_from([8, 16, 32]))
    page = draw(st.sampled_from([2, 4, 8]))
    nb = draw(st.integers(1, 4))
    bits = draw(st.sampled_from([8, 4, 32]))
    window = draw(st.sampled_from([None, 3, 7]))
    block_kv = draw(st.sampled_from([1, 2]))
    seed = draw(st.integers(0, 2 ** 16))
    return b, kv, g, dh, page, nb, bits, window, block_kv, seed


@given(paged_attn_case())
@settings(max_examples=10, deadline=None)
def test_paged_attention_kernel_matches_ref(case):
    """Fused Pallas decode kernel (in-kernel dequant, block-table walk)
    == jnp gather+softmax oracle for random pools, ragged per-slot fill
    levels, GQA ratios, kv-bits, windows, and block_kv tiles."""
    from repro.kernels.paged_attention import paged_attention
    from repro.kernels.ref import paged_attention_ref
    from repro.models.attention import quantize_kv

    b, kv, g, dh, page, nb, bits, window, block_kv, seed = case
    if block_kv > kv or kv % block_kv:
        block_kv = 1
    n_pages = 1 + b * nb
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, kv, g, dh), jnp.float32)
    kf = jax.random.normal(ks[1], (n_pages, page, kv, dh), jnp.float32)
    vf = jax.random.normal(ks[2], (n_pages, page, kv, dh), jnp.float32)
    if bits < 32:
        kq, ksc = quantize_kv(kf, bits)
        vq, vsc = quantize_kv(vf, bits)
    else:
        kq, vq, ksc, vsc = kf, vf, None, None
    table = jnp.arange(1, 1 + b * nb, dtype=jnp.int32).reshape(b, nb)
    kv_len = jax.random.randint(ks[3], (b,), 1,
                                nb * page + 1).astype(jnp.int32)
    got = paged_attention(q, kq, vq, ksc, vsc, table, kv_len,
                          window=window, block_kv=block_kv)
    want = paged_attention_ref(q, kq, vq, ksc, vsc, table, kv_len,
                               window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
