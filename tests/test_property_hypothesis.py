"""Property-based tests (hypothesis) for BWQ-A invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")          # optional dep; skip, don't error
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (BlockingSpec, adjust_precision, bitwidths, compose,
                        from_float, layer_bit_count, requantize)
from repro.core.blocking import block_elem_counts
from repro.core.fakequant import fq_from_float, fq_maintenance, fq_compose
from repro.kernels.ref import pack_bits, unpack_bits

SETTINGS = dict(max_examples=20, deadline=None)


@st.composite
def weight_case(draw):
    k = draw(st.integers(5, 40))
    n = draw(st.integers(5, 40))
    n_bits = draw(st.sampled_from([2, 4, 8]))
    wbr = draw(st.sampled_from([3, 8, 9]))
    wbc = draw(st.sampled_from([4, 8]))
    seed = draw(st.integers(0, 2 ** 16))
    scale = draw(st.floats(1e-3, 10.0))
    return k, n, n_bits, wbr, wbc, seed, scale


@given(weight_case())
@settings(**SETTINGS)
def test_reconstruction_bound(case):
    """|compose(from_float(w)) - w| <= scale / (2^n - 1) / 2 elementwise."""
    k, n, n_bits, wbr, wbc, seed, scale = case
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * scale
    qt = from_float(w, n_bits, BlockingSpec(wbr, wbc))
    err = np.max(np.abs(np.asarray(compose(qt) - w)))
    bound = float(jnp.max(jnp.abs(w))) / (2 ** n_bits - 1) / 2
    assert err <= bound * (1 + 1e-5) + 1e-9


@given(weight_case())
@settings(**SETTINGS)
def test_precision_adjustment_monotone_and_prefix(case):
    k, n, n_bits, wbr, wbc, seed, scale = case
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * scale
    qt = requantize(from_float(w, n_bits, BlockingSpec(wbr, wbc)))
    qt1 = adjust_precision(qt)
    mask = np.asarray(qt1.mask)
    # prefix property: once a bit is off, all higher bits are off
    for b in range(1, n_bits):
        assert np.all(mask[b] <= mask[b - 1] + 1e-9)
    # monotone under repetition
    qt2 = adjust_precision(requantize(qt1))
    assert np.all(np.asarray(bitwidths(qt2)) <= np.asarray(bitwidths(qt1)))


@given(weight_case())
@settings(**SETTINGS)
def test_requantize_composes_exactly_representable(case):
    """After requantize, compose is on the exact scale grid."""
    k, n, n_bits, wbr, wbc, seed, scale = case
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * scale
    qt = requantize(from_float(w, n_bits, BlockingSpec(wbr, wbc)))
    wq = np.asarray(compose(qt), dtype=np.float64)
    s = float(qt.scale) / (2 ** n_bits - 1)
    q = wq / s
    assert np.max(np.abs(q - np.round(q))) < 1e-3


@given(weight_case())
@settings(**SETTINGS)
def test_live_bits_match_numpy_reference(case):
    k, n, n_bits, wbr, wbc, seed, scale = case
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * scale
    spec = BlockingSpec(wbr, wbc)
    qt = adjust_precision(requantize(from_float(w, n_bits, spec)))
    elems = np.asarray(block_elem_counts((k, n), spec))
    bw = np.asarray(bitwidths(qt))
    assert float(layer_bit_count(qt)) == float((bw * elems).sum())


@given(weight_case())
@settings(**SETTINGS)
def test_fakequant_tracks_bitplane(case):
    """fake-quant compose == bit-plane compose for exact-binary states."""
    k, n, n_bits, wbr, wbc, seed, scale = case
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * scale
    spec = BlockingSpec(wbr, wbc)
    qt = requantize(adjust_precision(requantize(from_float(w, n_bits, spec))))
    fq = fq_from_float(w, n_bits, spec)
    fq = dataclasses.replace(
        fq, bitwidth=jnp.sum(qt.mask, axis=0).astype(fq.bitwidth.dtype))
    fq = fq_maintenance(fq)
    np.testing.assert_allclose(np.asarray(fq_compose(fq)),
                               np.asarray(compose(qt)),
                               atol=float(qt.scale) * 1e-5 + 1e-6)


@given(st.integers(1, 8), st.integers(1, 6), st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_pack_unpack_bits_roundtrip(rows8, cols, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(rows8 * 8, cols)).astype(np.float32)
    packed = pack_bits(jnp.asarray(bits))
    out = np.asarray(unpack_bits(packed))
    np.testing.assert_array_equal(out, bits)
