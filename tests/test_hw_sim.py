"""BWQ-H hardware model tests: controller (Alg. 2), mapping schemes
(Fig. 5), scheme orderings (Fig. 9), OU scaling (Fig. 13)."""
import numpy as np
import pytest

from repro.hw import (PAPER_SPEC, bsq_scheme, bwq_scheme, controller_cycles,
                      fc_workload, isaac_scheme, layer_mapping_cost,
                      lut_bits, run_controller, simulate, simulate_layer,
                      sme_scheme, speedup_and_energy_saving, sre_scheme,
                      wb_mapping_cost)


class TestController:
    def test_trace_matches_fig6b_structure(self):
        # two WB rows; row0: WBs of precision 2 and 1; row1: spare + 3
        tr = run_controller(np.array([[2, 1], [0, 3]]))
        assert tr.cycles == 6                    # 2+1+3 OU activations
        assert tr.ir_fetches == 2                # one per WB row
        assert tr.sna_skips == 3                 # one per non-spare WB
        # spare OU (row1, col0) never appears in the trace
        assert all(not (i == 1 and j == 0) for _, i, j, _ in tr.events)

    def test_cycles_scale_with_act_bits(self):
        bw = np.array([[4, 4], [4, 4]])
        assert controller_cycles(bw, act_bits=3) == 3 * 16

    def test_lut_size(self):
        assert lut_bits(np.zeros((10, 10)), max_bits=8) == 100 * 4


class TestMapping:
    def test_precision_aware_full_utilization(self):
        for bits in range(1, 9):
            mc = wb_mapping_cost(bits, 8, "precision_aware")
            assert mc.utilization == 1.0
            assert mc.ou_activations == bits
            assert mc.extra_sna_ops == 0

    def test_same_ou_spare_columns(self):
        # paper Fig 5(b): 3-bit weights, 8 cols -> 2 weights/OU, 25% waste
        mc = wb_mapping_cost(3, 8, "same_ou")
        assert mc.utilization == pytest.approx(0.75)

    def test_conventional_straddles_cost_sna(self):
        mc = wb_mapping_cost(3, 8, "conventional")
        assert mc.extra_sna_ops > 0
        assert mc.ou_activations == 3            # ceil(24/8)

    def test_divisible_case_all_equal(self):
        a = wb_mapping_cost(4, 8, "precision_aware")
        b = wb_mapping_cost(4, 8, "same_ou")
        assert a.ou_activations == b.ou_activations == 4

    def test_layer_aggregate(self):
        bw = np.array([[0, 1], [2, 8]])
        mc = layer_mapping_cost(bw, 8, "precision_aware")
        assert mc.ou_activations == 11


class TestSchemes:
    def _workloads(self):
        rng = np.random.default_rng(0)
        wls = []
        for i, (k, n) in enumerate([(576, 64), (1152, 128), (2304, 256)]):
            wl = fc_workload(f"fc{i}", k, n, positions=64, act_bits=3)
            wl.bitwidths = rng.choice([0, 1, 2, 3, 4],
                                      size=wl.bitwidths.shape,
                                      p=[.1, .3, .3, .2, .1])
            wls.append(wl)
        return wls

    def test_paper_ordering_speedup_and_energy(self):
        wls = self._workloads()
        base = isaac_scheme()
        res = {s.name: speedup_and_energy_saving(wls, s, base)
               for s in [bwq_scheme(), bsq_scheme(4), sre_scheme(),
                         sme_scheme()]}
        # paper Fig. 9: BWQ-H > BSQ > SME/SRE > ISAAC(=1)
        assert res["BWQ-H"][0] > res["BSQ"][0] > 1.0
        assert res["BWQ-H"][0] > res["SRE"][0] > 1.0
        assert res["BWQ-H"][1] > res["BSQ"][1] > 1.0

    def test_adc_dominates_energy(self):
        rep = simulate(self._workloads(), bwq_scheme())
        br = rep.energy_breakdown()
        assert br["adc"] > 0.5 * sum(br.values())

    def test_indexing_overhead_ordering(self):
        wls = self._workloads()
        idx = {s.name: simulate(wls, s).index_bits
               for s in [bwq_scheme(), sre_scheme(), sme_scheme(),
                         bsq_scheme(4)]}
        # paper Fig. 11: SRE >> BWQ > SME > BSQ(~0)
        assert idx["SRE"] > idx["BWQ-H"] > idx["SME"]
        assert idx["BSQ"] == 0.0

    def test_ou_size_energy_grows(self):
        """Paper Fig. 13: ADC energy (and total) grows with OU size."""
        energies = []
        for rows, cols in [(9, 8), (32, 32), (128, 128)]:
            spec = PAPER_SPEC.with_ou(rows, cols)
            wl2 = fc_workload("fc", 1152, 128, positions=64, act_bits=3,
                              weight_bits=4, spec=spec)
            energies.append(simulate([wl2], bsq_scheme(4), spec).energy_j)
        assert energies[0] < energies[1] < energies[2]

    def test_adc_precision_follows_ou_rows(self):
        assert PAPER_SPEC.adc_bits_for(9) == 4      # paper: 4-bit ADC @ 9 WLs
        assert PAPER_SPEC.adc_bits_for(128) == 8

    def test_zero_precision_blocks_cost_nothing(self):
        wl = fc_workload("fc", 72, 8, positions=1, act_bits=1)
        wl.bitwidths = np.zeros_like(wl.bitwidths)
        rep = simulate_layer(wl, bwq_scheme())
        assert rep.cycles == 0
