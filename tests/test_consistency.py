"""Numerical-equivalence tests between execution paths:

* prefill + decode == full forward (cache correctness, every family)
* chunked SSD / WKV == step-by-step recurrence
* blockwise (flash) attention == dense attention
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models.api import build
from repro.models.attention import attention_core, blockwise_attention_core
from repro.models.common import QuantConfig
from repro.models.rwkv import _wkv_chunked
from repro.models.ssm import _ssd_chunked
from repro.models import transformer

KEY = jax.random.PRNGKey(7)


def _tiny(name):
    return REGISTRY[name].tiny(dtype="float32").with_quant(
        QuantConfig(mode="none"))


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "gemma2-27b",
                                  "granite-moe-3b-a800m", "rwkv6-1.6b",
                                  "zamba2-1.2b", "qwen2-vl-2b"])
def test_prefill_decode_matches_full_forward(arch):
    """Teacher-forced decode over the cache must reproduce the full
    forward's logits at every position."""
    cfg = _tiny(arch)
    api = build(cfg)
    params = api.init(KEY)
    s = 12
    toks = jax.random.randint(jax.random.fold_in(KEY, 1), (2, s), 0,
                              cfg.vocab).astype(jnp.int32)
    batch = {"tokens": toks}
    tv = 0
    if cfg.family == "vlm":
        tv = cfg.vision_tokens
        batch["vision_embeds"] = jax.random.normal(
            jax.random.fold_in(KEY, 2), (2, tv, cfg.d_model)) * 0.1

    logits_full, _, _ = transformer.forward(
        params, cfg, toks, vision_embeds=batch.get("vision_embeds"))

    # prefill the first s-4 tokens, then feed each remaining token ONCE
    # (recurrent families double-apply re-fed tokens, unlike KV caches)
    cut = s - 4
    pre = dict(batch, tokens=toks[:, :cut])
    _, state = api.prefill(params, pre, extra_slots=8)
    for i in range(cut, s):
        logits_i, state = api.decode_step(
            params, toks[:, i:i + 1], state, jnp.asarray(tv + i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits_i), np.asarray(logits_full[:, tv + i]),
            rtol=2e-3, atol=2e-3)


def test_ssd_chunked_equals_stepwise():
    b, L, H, P, N = 2, 64, 3, 8, 5
    k = jax.random.fold_in(KEY, 3)
    xh = jax.random.normal(k, (b, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1),
                                           (b, L, H)))
    da = -jnp.abs(jax.random.normal(jax.random.fold_in(k, 2), (b, L, H))) * .3
    B = jax.random.normal(jax.random.fold_in(k, 3), (b, L, N))
    C = jax.random.normal(jax.random.fold_in(k, 4), (b, L, N))
    h0 = jnp.zeros((b, H, N, P))

    y_chunk, h_chunk = _ssd_chunked(xh, dt, da, B, C, h0, chunk=16)

    # reference stepwise recurrence
    h = np.zeros((b, H, N, P))
    ys = []
    for t in range(L):
        h = h * np.exp(np.asarray(da[:, t]))[:, :, None, None] + \
            np.einsum("bn,bh,bhp->bhnp", np.asarray(B[:, t]),
                      np.asarray(dt[:, t]), np.asarray(xh[:, t]))
        ys.append(np.einsum("bn,bhnp->bhp", np.asarray(C[:, t]), h))
    y_ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), y_ref, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_chunk), h, rtol=1e-4, atol=1e-4)


def test_wkv_chunked_equals_stepwise():
    b, L, H, K = 2, 64, 2, 8
    k = jax.random.fold_in(KEY, 9)
    r = jax.random.normal(k, (b, L, H, K))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (b, L, H, K))
    v = jax.random.normal(jax.random.fold_in(k, 2), (b, L, H, K))
    logw = -jnp.abs(jax.random.normal(jax.random.fold_in(k, 3),
                                      (b, L, H, K))) * 0.5
    u = jax.random.normal(jax.random.fold_in(k, 4), (H, K)) * 0.1
    s0 = jnp.zeros((b, H, K, K))

    o_chunk, s_chunk = _wkv_chunked(r, kk, v, logw, u, s0, chunk=16)

    s = np.zeros((b, H, K, K))
    os_ = []
    for t in range(L):
        rt, kt, vt = (np.asarray(a[:, t]) for a in (r, kk, v))
        o_t = np.einsum("bhk,bhkv->bhv", rt, s) + \
            np.einsum("bhk,hk,bhk,bhv->bhv", rt, np.exp(np.asarray(u)),
                      kt, vt)
        s = s * np.exp(np.asarray(logw[:, t]))[..., None] + \
            np.einsum("bhk,bhv->bhkv", kt, vt)
        os_.append(o_t)
    o_ref = np.stack(os_, axis=1)
    np.testing.assert_allclose(np.asarray(o_chunk), o_ref, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), s, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("window", [0, 24])
def test_blockwise_attention_equals_dense(window):
    b, s, h, kv, dh = 2, 128, 4, 2, 16
    k = jax.random.fold_in(KEY, 11)
    q = jax.random.normal(k, (b, s, h, dh))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (b, s, kv, dh))
    v = jax.random.normal(jax.random.fold_in(k, 2), (b, s, kv, dh))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    dense = attention_core(q, kk, v, pos, pos, causal=True, window=window)
    block = blockwise_attention_core(q, kk, v, pos, pos, causal=True,
                                     window=window, q_block=32, kv_block=64)
    np.testing.assert_allclose(np.asarray(block), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)


def test_blockwise_attention_softcap_and_grad():
    b, s, h, kv, dh = 1, 64, 2, 2, 8
    q = jax.random.normal(KEY, (b, s, h, dh))
    kk = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, kv, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kv, dh))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def f_dense(q):
        return jnp.sum(attention_core(q, kk, v, pos, pos, causal=True,
                                      attn_softcap=20.0) ** 2)

    def f_block(q):
        return jnp.sum(blockwise_attention_core(
            q, kk, v, pos, pos, causal=True, attn_softcap=20.0,
            q_block=16, kv_block=16) ** 2)

    np.testing.assert_allclose(float(f_block(q)), float(f_dense(q)),
                               rtol=1e-4)
    g1, g2 = jax.grad(f_dense)(q), jax.grad(f_block)(q)
    np.testing.assert_allclose(np.asarray(g2), np.asarray(g1), rtol=1e-3,
                               atol=1e-4)
