"""Unit tests for BWQ-A core: bit representation, blocking, precision,
group Lasso, PACT, fake-quant equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BlockingSpec, adjust_precision,
                        bitwidths, compose, extract_planes, from_float,
                        layer_bit_count, pact, pact_quant, pact_sym,
                        model_compression_ratio, pack, quant_summary,
                        regularization_loss, requantize, unpack_to_float,
                        wb_group_lasso)
from repro.core.blocking import (block_elem_counts, block_view,
                                 conv_from_2d, conv_to_2d, expand_block_map,
                                 pad_to_blocks, unblock_view)
from repro.core.fakequant import (fq_compose, fq_from_float, fq_live_bits,
                                  fq_maintenance)

KEY = jax.random.PRNGKey(0)


class TestBlocking:
    def test_block_roundtrip(self):
        spec = BlockingSpec(9, 8)
        w = jax.random.normal(KEY, (27, 24))
        bv = block_view(w, spec)
        assert bv.shape == (3, 3, 9, 8)
        np.testing.assert_array_equal(unblock_view(bv, spec), w)

    def test_conv_reshape_roundtrip(self):
        w = jax.random.normal(KEY, (16, 3, 3, 3))
        w2 = conv_to_2d(w)
        assert w2.shape == (27, 16)
        np.testing.assert_array_equal(conv_from_2d(w2, w.shape), w)

    def test_expand_block_map(self):
        spec = BlockingSpec(2, 3)
        m = jnp.arange(6).reshape(2, 3).astype(jnp.float32)
        full = expand_block_map(m, spec)
        assert full.shape == (4, 9)
        assert full[0, 0] == 0 and full[3, 8] == 5 and full[1, 4] == 1

    def test_block_elem_counts_partial_edges(self):
        spec = BlockingSpec(9, 8)
        counts = np.asarray(block_elem_counts((20, 13), spec))
        assert counts.sum() == 20 * 13
        assert counts[0, 0] == 72 and counts[-1, -1] == 2 * 5

    def test_padding(self):
        spec = BlockingSpec(9, 8)
        w = jnp.ones((10, 9))
        wp = pad_to_blocks(w, spec)
        assert wp.shape == (18, 16)
        assert float(wp[10:, :].sum()) == 0.0


class TestBitRep:
    def test_reconstruction_error_bound(self):
        w = jax.random.normal(KEY, (36, 32)) * 0.3
        qt = from_float(w, n_bits=8)
        err = jnp.max(jnp.abs(compose(qt) - w))
        bound = jnp.max(jnp.abs(w)) / (2 ** 8 - 1) / 2 * 1.001
        assert err <= bound

    def test_extract_planes_exact(self):
        q = jnp.asarray([[0., 1.], [5., 255.]])
        planes = extract_planes(q, 8)
        recon = sum(planes[b] * 2 ** b for b in range(8))
        np.testing.assert_array_equal(recon, q)

    def test_requantize_idempotent_on_exact(self):
        w = jax.random.normal(KEY, (18, 16)) * 0.1
        qt = requantize(from_float(w, 8))
        qt2 = requantize(qt)
        np.testing.assert_allclose(compose(qt), compose(qt2))

    def test_stacked_layers(self):
        w = jax.random.normal(KEY, (3, 18, 16)) * 0.1
        qt = from_float(w, 8)
        assert qt.planes.shape == (8, 3, 18, 16)
        assert compose(qt).shape == (3, 18, 16)
        err = jnp.max(jnp.abs(compose(qt) - w))
        assert err < jnp.max(jnp.abs(w)) / 255

    def test_grads_flow_to_planes_not_masked(self):
        w = jax.random.normal(KEY, (18, 16)) * 0.1
        qt = from_float(w, 8)
        qt = dataclasses.replace(qt, mask=qt.mask.at[7].set(0.0))

        g = jax.grad(lambda q: jnp.sum(compose(q) ** 2))(qt)
        # masked plane gets zero gradient -> pruned bits never revive
        assert float(jnp.abs(g.planes[7]).max()) == 0.0
        assert float(jnp.abs(g.planes[0]).max()) > 0.0

    def test_pack_unpack_roundtrip(self):
        w = jax.random.normal(KEY, (18, 16)) * 0.1
        qt = requantize(from_float(w, 8))
        pw = pack(qt)
        np.testing.assert_allclose(unpack_to_float(pw, qt.spec), compose(qt),
                                   atol=1e-7)


class TestPrecisionAdjustment:
    def _qt(self, w):
        return requantize(from_float(w, 8))

    def test_msb_down_removal(self):
        w = jnp.full((9, 8), 0.1)        # one block
        w = w.at[0, 0].set(1.0)          # max sets scale
        qt = self._qt(w)
        qt2 = adjust_precision(qt)
        bw = float(bitwidths(qt2)[0, 0])
        # 0.1/1.0*255 = 25.5 -> 26 needs 5 bits; 255 needs 8 -> block keeps 8
        assert bw == 8.0

    def test_low_magnitude_block_gets_fewer_bits(self):
        w = jnp.zeros((18, 8))
        w = w.at[0, 0].set(1.0)          # block 0: scale setter (8 bits)
        w = w.at[9:, :].set(0.01)        # block 1: 0.01*255 = 2.55 -> 3 -> 2 bits
        qt = adjust_precision(self._qt(w))
        bw = np.asarray(bitwidths(qt))
        assert bw[0, 0] == 8 and bw[1, 0] == 2

    def test_monotone_never_grows(self):
        w = jax.random.normal(KEY, (36, 32)) * 0.2
        qt = adjust_precision(self._qt(w))
        bw1 = np.asarray(bitwidths(qt))
        # make weights large again; masked planes stay off
        qt = dataclasses.replace(qt, planes=jnp.ones_like(qt.planes))
        qt2 = adjust_precision(requantize(qt))
        bw2 = np.asarray(bitwidths(qt2))
        assert (bw2 <= bw1).all()

    def test_all_zero_block_removed(self):
        w = jnp.zeros((9, 16))
        w = w.at[:, 8:].set(0.5)
        qt = adjust_precision(self._qt(w))
        bw = np.asarray(bitwidths(qt))
        assert bw[0, 0] == 0 and bw[0, 1] > 0


class TestGroupLasso:
    def test_positive_and_zero_when_masked(self):
        w = jax.random.normal(KEY, (18, 16)) * 0.1
        qt = from_float(w, 8)
        assert float(wb_group_lasso(qt)) > 0
        qt0 = dataclasses.replace(qt, mask=jnp.zeros_like(qt.mask))
        assert float(wb_group_lasso(qt0)) == pytest.approx(0.0)

    def test_regularization_layer_weighting(self):
        w1 = jax.random.normal(KEY, (18, 16)) * 0.1
        qts = {"a": from_float(w1, 8)}
        r1 = float(regularization_loss(qts, alpha=1e-3))
        assert r1 > 0
        assert float(regularization_loss(qts, alpha=0.0)) == 0.0

    def test_compression_ratio(self):
        w = jax.random.normal(KEY, (18, 16)) * 0.1
        qt = from_float(w, 8)
        assert model_compression_ratio([qt]) == pytest.approx(4.0)

    def test_gradient_shrinks_bits(self):
        w = jax.random.normal(KEY, (18, 16)) * 0.1
        qt = from_float(w, 8)
        g = jax.grad(lambda q: wb_group_lasso(q))(qt)
        # gradient direction is positive on positive plane values (shrink)
        nz = np.asarray(qt.planes) > 0
        assert (np.asarray(g.planes)[nz] > 0).all()


class TestPACT:
    def test_eq4_piecewise(self):
        beta = jnp.asarray(1.5)
        assert float(pact(jnp.asarray(-3.0), beta)) == 0.0
        assert float(pact(jnp.asarray(0.7), beta)) == pytest.approx(0.7)
        assert float(pact(jnp.asarray(9.0), beta)) == pytest.approx(1.5)

    def test_beta_gradient_on_saturated_side(self):
        g = jax.grad(lambda b: pact(jnp.asarray(5.0), b))(jnp.asarray(1.5))
        assert float(g) == pytest.approx(1.0)
        g2 = jax.grad(lambda b: pact(jnp.asarray(0.5), b))(jnp.asarray(1.5))
        assert float(g2) == pytest.approx(0.0)

    def test_quant_levels(self):
        x = jnp.linspace(0, 1.5, 100)
        y = pact_quant(x, jnp.asarray(1.5), 2)
        assert len(np.unique(np.asarray(y).round(6))) <= 4

    def test_symmetric_clip(self):
        x = jnp.asarray([-5.0, -0.3, 0.3, 5.0])
        y = pact_sym(x, jnp.asarray(1.0))
        np.testing.assert_allclose(y, [-1.0, -0.3, 0.3, 1.0], atol=1e-6)


class TestFakeQuantEquivalence:
    def test_matches_bitplane_on_exact_states(self):
        w = jax.random.normal(KEY, (36, 32)) * 0.2
        qt = adjust_precision(requantize(from_float(w, 8)))
        qt = requantize(qt)
        fq = fq_from_float(w, 8)
        fq = dataclasses.replace(
            fq, bitwidth=jnp.sum(qt.mask, axis=0).astype(fq.bitwidth.dtype))
        fq = fq_maintenance(fq)
        np.testing.assert_allclose(np.asarray(fq_compose(fq)),
                                   np.asarray(compose(qt)), atol=2e-6)

    def test_live_bits_agree(self):
        w = jax.random.normal(KEY, (36, 32)) * 0.2
        qt = adjust_precision(requantize(from_float(w, 8)))
        fq = fq_maintenance(fq_from_float(w, 8))
        assert float(fq_live_bits(fq)) == pytest.approx(
            float(layer_bit_count(qt)))

    def test_maintenance_monotone(self):
        w = jax.random.normal(KEY, (36, 32)) * 0.2
        fq = fq_maintenance(fq_from_float(w, 8))
        bw1 = np.asarray(fq.bitwidth)
        fq2 = fq_maintenance(fq)
        assert (np.asarray(fq2.bitwidth) <= bw1).all()


def test_quant_summary_structure():
    w = jax.random.normal(KEY, (18, 16)) * 0.1
    s = quant_summary({"layer": {"w": from_float(w, 8)}})
    assert s["layers"] == 1 and s["avg_bitwidth"] == pytest.approx(8.0)
