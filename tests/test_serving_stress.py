"""Randomized continuous-batching stress harness.

Draws whole serving workloads — arrival times, prompt lengths,
``max_new_tokens``, slot counts, KV precision, page size, prefill chunk
width, EOS cut-offs — and checks the two invariants the scheduler
guarantees:

* every request's tokens are identical to its one-shot ``generate()``
  output (greedy), no matter how it was staggered, paged, chunked, or
  slot-recycled;
* the page pool leaks nothing: after the queue drains, every page is back
  on the free list and all block tables point at the trash page.

Runs under `hypothesis` when installed, else the deterministic fallback
driver (`repro.testing.proptest`).  The whole module is `slow` (it
compiles many prompt shapes); CI's fast tier skips it, the full tier and
plain `pytest` run it.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                        # optional dep: seeded fallback
    from repro.testing import proptest as _pt
    given, settings, st = _pt.given, _pt.settings, _pt

from repro.configs import REGISTRY
from repro.models.api import build
from repro.models.common import QuantConfig
from repro.serve import Request, SamplingParams, ServeEngine
from repro.serve.deploy import to_serving_params
from repro.serve.scheduler import Scheduler

pytestmark = pytest.mark.slow


@functools.lru_cache(maxsize=None)
def _engine(arch: str, kv_bits: int, backend: str = "dense",
            deploy=None) -> ServeEngine:
    """One engine per (arch, kv, backend, deploy) so jit caches amortize
    across examples.  ``deploy`` is an optional (bits, layout) pair that
    converts the QAT tree to serving weights first."""
    cfg = REGISTRY[arch].tiny(dtype="float32").with_quant(
        QuantConfig(mode="fake", n_bits=8, act_bits=8))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    if deploy is not None:
        bits, layout = deploy
        params = to_serving_params(params, bits, layout=layout)
    return ServeEngine(api, params, kv_quant_bits=kv_bits, backend=backend)


# prompt lengths drawn from a small pool so prefill compiles are reused
_PROMPT_LENS = (1, 2, 3, 5, 8, 11, 16)


@st.composite
def workload(draw):
    arch = draw(st.sampled_from(["phi3-mini-3.8b", "granite-moe-3b-a800m"]))
    kv_bits = draw(st.sampled_from([32, 8, 4]))
    n_slots = draw(st.integers(1, 4))
    page_size = draw(st.sampled_from([0, 3, 4, 8]))
    prefill_chunk = draw(st.sampled_from([0, 4]))
    n_req = draw(st.integers(3, 6))
    reqs = [dict(prompt_len=draw(st.sampled_from(_PROMPT_LENS)),
                 max_new=draw(st.integers(1, 8)),
                 arrival=draw(st.integers(0, 12)),
                 eos_cut=draw(st.sampled_from([0, 0, 2, 3])),
                 seed=draw(st.integers(0, 2 ** 16)))
            for _ in range(n_req)]
    return arch, kv_bits, n_slots, page_size, prefill_chunk, reqs


def _run_workload(arch, kv_bits, n_slots, page_size, prefill_chunk, specs):
    eng = _engine(arch, kv_bits)
    cfg = eng.api.cfg
    requests, expected = [], []
    for uid, spec in enumerate(specs):
        toks = jax.random.randint(jax.random.PRNGKey(spec["seed"]),
                                  (1, spec["prompt_len"]), 0,
                                  cfg.vocab).astype(jnp.int32)
        ref = np.asarray(eng.generate({"tokens": toks},
                                      max_new=spec["max_new"]))[0].tolist()
        # eos_cut > 0 forces an early 'stop' at that reference token
        eos_id = None
        if 0 < spec["eos_cut"] <= len(ref):
            eos_id = ref[spec["eos_cut"] - 1]
            ref = ref[:ref.index(eos_id) + 1]
        requests.append(Request(
            uid=uid, inputs={"tokens": toks},
            sampling=SamplingParams(max_new_tokens=spec["max_new"],
                                    eos_id=eos_id),
            arrival=spec["arrival"]))
        expected.append(ref)
    sched = eng.make_scheduler(requests, n_slots=n_slots,
                               page_size=page_size,
                               prefill_chunk=prefill_chunk)
    results = sched.run(requests)
    for r, ref in zip(results, expected):
        assert r.tokens == ref, (
            f"uid {r.uid}: {r.tokens} != one-shot {ref} "
            f"(slots={n_slots} page={page_size} chunk={prefill_chunk} "
            f"kv={kv_bits})")
        eos = requests[r.uid].sampling.eos_id
        assert r.finish_reason == \
            ("stop" if eos is not None and ref[-1] == eos else "length")
    if page_size:
        rep = sched.cache_report()
        assert rep["pages_in_use"] == 0, f"leaked pages: {rep}"
        assert sched.allocator.free_count == sched.allocator.n_pages - 1
        assert sched.allocator.reserved == 0, "leaked page reservations"
        assert (sched.tables == 0).all(), "block table not returned to trash"
    return sched


@given(workload())
@settings(max_examples=4, deadline=None)
def test_randomized_serving_matches_generate(case):
    _run_workload(*case)


# ---------------------------------------------------------------------------
# bitplane execution backend under the randomized harness: the plane-
# sliced kernel must survive paged block tables + chunked prefill with
# token parity against ONE-SHOT DENSE generate on the same deployed
# weights, and drain the page pool leak-free
# ---------------------------------------------------------------------------

@st.composite
def bitplane_workload(draw):
    n_slots = draw(st.integers(1, 3))
    page_size = draw(st.sampled_from([3, 4, 8]))       # always paged
    prefill_chunk = draw(st.sampled_from([0, 4]))
    n_req = draw(st.integers(3, 5))
    reqs = [dict(prompt_len=draw(st.sampled_from((2, 5, 8, 11))),
                 max_new=draw(st.integers(1, 6)),
                 arrival=draw(st.integers(0, 8)),
                 seed=draw(st.integers(0, 2 ** 16)))
            for _ in range(n_req)]
    return n_slots, page_size, prefill_chunk, reqs


@given(bitplane_workload())
@settings(max_examples=2, deadline=None)
def test_bitplane_backend_randomized_serving(case):
    n_slots, page_size, prefill_chunk, specs = case
    deploy = (8, "bitplane")
    dense = _engine("phi3-mini-3.8b", 8, "dense", deploy)
    eng = _engine("phi3-mini-3.8b", 8, "bitplane", deploy)
    cfg = eng.api.cfg
    requests, expected = [], []
    for uid, spec in enumerate(specs):
        toks = jax.random.randint(jax.random.PRNGKey(spec["seed"]),
                                  (1, spec["prompt_len"]), 0,
                                  cfg.vocab).astype(jnp.int32)
        expected.append(np.asarray(dense.generate(
            {"tokens": toks}, max_new=spec["max_new"]))[0].tolist())
        requests.append(Request(
            uid=uid, inputs={"tokens": toks},
            sampling=SamplingParams(max_new_tokens=spec["max_new"]),
            arrival=spec["arrival"]))
    sched = eng.make_scheduler(requests, n_slots=n_slots,
                               page_size=page_size,
                               prefill_chunk=prefill_chunk)
    results = sched.run(requests)
    for r, ref in zip(results, expected):
        assert r.tokens == ref, (
            f"uid {r.uid}: bitplane {r.tokens} != one-shot dense {ref} "
            f"(slots={n_slots} page={page_size} chunk={prefill_chunk})")
    rep = sched.cache_report()
    assert rep["pages_in_use"] == 0, f"leaked pages: {rep}"
    assert sched.allocator.free_count == sched.allocator.n_pages - 1
    assert sched.allocator.reserved == 0, "leaked page reservations"
    assert (sched.tables == 0).all(), "block table not returned to trash"


# ---------------------------------------------------------------------------
# preemption + shared-prefix leg: overcommitted admission parks victims to
# host memory and resumes them bit-identically, while duplicated prompt
# prefixes ride the refcounted prefix cache — tokens must still match
# one-shot generate, and the drained pool must hold zero pages AND zero
# outstanding refcounts
# ---------------------------------------------------------------------------

@st.composite
def preemption_workload(draw):
    arch = draw(st.sampled_from(["phi3-mini-3.8b", "granite-moe-3b-a800m"]))
    kv_bits = draw(st.sampled_from([32, 8, 4]))
    n_slots = draw(st.integers(2, 4))
    page_size = draw(st.sampled_from([3, 4]))          # always paged
    prefill_chunk = draw(st.sampled_from([0, 4]))
    overcommit = draw(st.sampled_from([1.5, 2.0, 3.0]))
    shared_len = draw(st.sampled_from([5, 8, 9]))      # duplicated prefix
    n_req = draw(st.integers(4, 7))
    reqs = [dict(tail_len=draw(st.integers(1, 6)),
                 shared=draw(st.booleans()),
                 max_new=draw(st.integers(1, 8)),
                 arrival=draw(st.integers(0, 6)),
                 priority=draw(st.integers(0, 2)),
                 seed=draw(st.integers(0, 2 ** 16)))
            for _ in range(n_req)]
    return (arch, kv_bits, n_slots, page_size, prefill_chunk, overcommit,
            shared_len, reqs)


@given(preemption_workload())
@settings(max_examples=4, deadline=None)
def test_randomized_preemption_and_prefix_sharing(case):
    (arch, kv_bits, n_slots, page_size, prefill_chunk, overcommit,
     shared_len, specs) = case
    eng = _engine(arch, kv_bits)
    cfg = eng.api.cfg
    shared = jax.random.randint(jax.random.PRNGKey(99), (1, shared_len), 0,
                                cfg.vocab).astype(jnp.int32)
    requests, expected, worst = [], [], 0
    for uid, spec in enumerate(specs):
        tail = jax.random.randint(jax.random.PRNGKey(spec["seed"]),
                                  (1, spec["tail_len"]), 0,
                                  cfg.vocab).astype(jnp.int32)
        toks = jnp.concatenate([shared, tail], 1) if spec["shared"] else tail
        expected.append(np.asarray(eng.generate(
            {"tokens": toks}, max_new=spec["max_new"]))[0].tolist())
        requests.append(Request(
            uid=uid, inputs={"tokens": toks},
            sampling=SamplingParams(max_new_tokens=spec["max_new"],
                                    priority=spec["priority"]),
            arrival=spec["arrival"]))
        worst = max(worst, -(-(toks.shape[1] + spec["max_new"] - 1)
                             // page_size))
    # pool sized to the single largest request plus one page: admission
    # stays possible for everything, but concurrent decode growth under
    # overcommit MUST preempt
    sched = eng.make_scheduler(requests, n_slots=n_slots,
                               page_size=page_size, n_pages=worst + 2,
                               prefill_chunk=prefill_chunk,
                               overcommit=overcommit, prefix_cache=True)
    results = sched.run(requests)
    for r, ref in zip(results, expected):
        assert r.tokens == ref, (
            f"uid {r.uid}: {r.tokens} != one-shot {ref} "
            f"(slots={n_slots} page={page_size} chunk={prefill_chunk} "
            f"kv={kv_bits} overcommit={overcommit} "
            f"preemptions={sched.sched_stats['preemptions']})")
    rep = sched.cache_report()
    assert rep["pages_in_use"] == 0, f"leaked pages: {rep}"
    assert sched.allocator.free_count == sched.allocator.n_pages - 1
    assert sched.allocator.reserved == 0, "leaked page reservations"
    assert (sched.tables == 0).all(), "block table not returned to trash"
    assert rep["prefix_outstanding_refs"] == 0, f"leaked refcounts: {rep}"
    assert len(sched.prefix_cache) == 0, "drained cache still holds pages"
    assert not sched.validate(), sched.validate()


def test_tight_pool_blocks_admission_then_drains():
    """A pool far smaller than worst case forces head-of-line waiting;
    every request must still finish with exact tokens and no page leaks."""
    eng = _engine("phi3-mini-3.8b", 8)
    cfg = eng.api.cfg
    specs = [dict(prompt_len=8, max_new=6, arrival=0, eos_cut=0,
                  seed=100 + i) for i in range(6)]
    requests, expected = [], []
    for uid, spec in enumerate(specs):
        toks = jax.random.randint(jax.random.PRNGKey(spec["seed"]),
                                  (1, spec["prompt_len"]), 0,
                                  cfg.vocab).astype(jnp.int32)
        expected.append(np.asarray(eng.generate(
            {"tokens": toks}, max_new=spec["max_new"]))[0].tolist())
        requests.append(Request(uid=uid, inputs={"tokens": toks},
                                sampling=SamplingParams(max_new_tokens=6),
                                arrival=0))
    # 8 + 6 - 1 = 13 positions -> 4 pages/request reserved; a pool of 9
    # live pages admits at most 2 concurrent requests though 4 slots exist
    sched = eng.make_scheduler(requests, n_slots=4, page_size=4,
                               n_pages=10)
    results = sched.run(requests)
    for r, ref in zip(results, expected):
        assert r.tokens == ref
    rep = sched.cache_report()
    assert rep["pages_in_use"] == 0
    assert rep["peak_pages_in_use"] <= 8       # 2 concurrent x 4 pages
    assert sched.allocator.free_count == 9
    assert sched.allocator.reserved == 0
    assert (sched.tables == 0).all()


def test_oversized_request_rejected_up_front():
    eng = _engine("phi3-mini-3.8b", 8)
    toks = jnp.zeros((1, 8), jnp.int32)
    sched = Scheduler(eng, n_slots=2, max_len=32, page_size=4, n_pages=4)
    with pytest.raises(ValueError, match="pool capacity"):
        sched.submit(Request(uid=0, inputs={"tokens": toks},
                             sampling=SamplingParams(max_new_tokens=16)))


def test_padded_final_chunk_respects_cache_extent():
    """Regression: with a tight max_len, the final chunk's compile-shape
    padding must stop at the slot's cache extent — an overflowing write
    would clamp backwards onto real prompt K/V (contiguous) or alias
    in-page offsets over the last prompt page (paged)."""
    eng = _engine("phi3-mini-3.8b", 8)
    cfg = eng.api.cfg
    toks = jax.random.randint(jax.random.PRNGKey(11), (1, 17), 0,
                              cfg.vocab).astype(jnp.int32)
    ref = np.asarray(eng.generate({"tokens": toks}, max_new=4))[0].tolist()
    for page in (0, 4):
        sched = Scheduler(eng, n_slots=1, max_len=20, page_size=page,
                          prefill_chunk=16)
        res = sched.run([Request(uid=0, inputs={"tokens": toks},
                                 sampling=SamplingParams(
                                     max_new_tokens=4))])
        assert res[0].tokens == ref, f"page_size={page}"


def test_chunked_prefill_interleaves_with_decode():
    """A long prompt inserted in chunks must not stall short requests:
    the short request admitted on the same tick finishes first, and both
    match one-shot decoding."""
    eng = _engine("phi3-mini-3.8b", 8)
    cfg = eng.api.cfg
    long_toks = jax.random.randint(jax.random.PRNGKey(7), (1, 16), 0,
                                   cfg.vocab).astype(jnp.int32)
    short_toks = jax.random.randint(jax.random.PRNGKey(8), (1, 2), 0,
                                    cfg.vocab).astype(jnp.int32)
    ref_long = np.asarray(eng.generate({"tokens": long_toks},
                                       max_new=4))[0].tolist()
    ref_short = np.asarray(eng.generate({"tokens": short_toks},
                                        max_new=3))[0].tolist()
    reqs = [Request(uid=0, inputs={"tokens": long_toks},
                    sampling=SamplingParams(max_new_tokens=4), arrival=0),
            Request(uid=1, inputs={"tokens": short_toks},
                    sampling=SamplingParams(max_new_tokens=3), arrival=0)]
    sched = eng.make_scheduler(reqs, n_slots=2, page_size=4,
                               prefill_chunk=4)
    results = sched.run(reqs)
    assert results[0].tokens == ref_long
    assert results[1].tokens == ref_short
    # 16/4 = 4 chunks -> the long prompt's first token lands on tick 3;
    # the short request decoded from tick 0 and finished before that
    assert results[1].finished_tick < results[0].admitted_tick + 4
    assert sched.cache_report()["pages_in_use"] == 0
