"""Priority scheduling, preemption, and prefix-cache unit tests.

Fast-tier companions to the randomized stress legs in
tests/test_serving_stress.py:

* :class:`PageAllocator` free-list determinism (min-heap: allocation
  always returns the globally lowest free id, even after churn) and
  overcommit admission arithmetic;
* ``cache_report`` charges the fixed-width equivalent its *ceil* block
  count (``max_len`` not divisible by ``page_size`` rounds up, exactly
  as a fixed layout would);
* priority classes order admission (higher first, ties by arrival then
  submission) and blocked requests are skipped over, not head-of-line
  stalled;
* ``park_slot``/``restore_slot`` round-trip a slot's pool pages and
  state rows bit-identically through host memory;
* the copy-on-write guard privatizes shared prefix pages without
  perturbing tokens, refcounts, or pool accounting.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models.api import build
from repro.models.common import QuantConfig
from repro.serve import Request, SamplingParams, ServeEngine
from repro.serve.scheduler import PageAllocator, PrefixCache

_ENGINES = {}


def _engine(kv_bits=8):
    if kv_bits not in _ENGINES:
        cfg = REGISTRY["phi3-mini-3.8b"].tiny(dtype="float32").with_quant(
            QuantConfig(mode="fake", n_bits=8, act_bits=8))
        api = build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        _ENGINES[kv_bits] = ServeEngine(api, params, kv_quant_bits=kv_bits)
    return _ENGINES[kv_bits]


def _req(uid, n_tokens, max_new=4, arrival=0, priority=0, seed=None,
         tokens=None):
    cfg = _engine().api.cfg
    if tokens is None:
        tokens = jax.random.randint(
            jax.random.PRNGKey(seed if seed is not None else 40 + uid),
            (1, n_tokens), 0, cfg.vocab).astype(jnp.int32)
    return Request(uid=uid, inputs={"tokens": tokens},
                   sampling=SamplingParams(max_new_tokens=max_new,
                                           priority=priority),
                   arrival=arrival)


# ---------------------------------------------------------------------------
# PageAllocator: min-ordered free list + overcommit arithmetic
# ---------------------------------------------------------------------------

def test_allocator_always_pops_lowest_free_id():
    """Regression: release() used to append released ids to the tail of
    the free list, so the next alloc returned the just-released pages
    instead of the globally lowest id — churn made traces
    order-dependent.  The min-heap must always pop the lowest."""
    a = PageAllocator(10)
    first = a.alloc(5)
    assert first == [1, 2, 3, 4, 5]
    a.release([2, 4])
    assert a.alloc(3) == [2, 4, 6], "released ids must re-sort into place"
    a.release([1, 5, 3])
    # lowest-first across releases from different eras, in one alloc
    assert a.alloc(4) == [1, 3, 5, 7]
    a.release([6, 2, 7, 1, 3, 4, 5])
    assert a.alloc(2) == [1, 2]


def test_allocator_release_order_does_not_change_allocation():
    """The same multiset of frees yields the same allocations regardless
    of release order (the determinism the class docstring promises)."""
    def churn(release_order):
        a = PageAllocator(8)
        a.alloc(7)
        for p in release_order:
            a.release([p])
        return a.alloc(4)
    assert churn([3, 1, 7, 5, 2]) == churn([7, 5, 3, 2, 1]) \
        == churn([1, 2, 3, 5, 7]) == [1, 2, 3, 5]


def test_allocator_overcommit_admission():
    a = PageAllocator(5, overcommit=2.0)       # 4 live pages, cap 8
    assert a.can_admit(8) and not a.can_admit(9)
    assert not a.can_admit(5, now=5), "immediate need is physical"
    a.reserved += 6
    assert a.can_admit(2) and not a.can_admit(3)
    strict = PageAllocator(5)                  # overcommit 1.0 = old rule
    assert strict.can_admit(4) and not strict.can_admit(5)
    with pytest.raises(ValueError, match="overcommit"):
        PageAllocator(5, overcommit=0.5)


# ---------------------------------------------------------------------------
# PrefixCache ledger
# ---------------------------------------------------------------------------

def test_prefix_cache_refcounts_and_release():
    pc = PrefixCache()
    h1 = PrefixCache.chain(b"", np.arange(4))
    h2 = PrefixCache.chain(h1, np.arange(4, 8))
    assert h1 != h2
    pc.register(h1, 3)
    pc.register(h2, 5)
    assert pc.lookup(h1) == 3 and pc.lookup(b"missing") is None
    pc.acquire(3)
    assert pc.refcounts == {3: 2, 5: 1}
    assert pc.release(3) is False              # one ref left
    assert pc.release(5) is True               # page freed to the caller
    assert pc.release(3) is True
    assert len(pc) == 0 and pc.outstanding_refs == 0
    assert pc.hits == 1 and pc.lookups == 2
    with pytest.raises(ValueError, match="already registered"):
        pc.register(h1, 7)
        pc.register(h1, 8)


# ---------------------------------------------------------------------------
# cache_report: ceil fixed-width equivalent
# ---------------------------------------------------------------------------

def test_cache_report_fixed_equiv_uses_ceil_blocks():
    """Regression: ``fixed_equiv_bytes`` used floor division
    (``max_len // page_size``), understating the fixed layout whenever
    the page size does not divide max_len — a fixed cache rounds every
    row up to whole pages too."""
    eng = _engine()
    reqs = [_req(0, 4, max_new=3)]
    sched = eng.make_scheduler(reqs, n_slots=2, max_len=10, page_size=4)
    sched.run(reqs)
    rep = sched.cache_report()
    assert sched.nb == 3                       # ceil(10 / 4)
    assert rep["fixed_equiv_bytes"] == rep["page_bytes"] * 2 * 3, \
        "floor division would charge only 2 blocks per slot"


# ---------------------------------------------------------------------------
# priority classes + skip-over admission
# ---------------------------------------------------------------------------

def test_higher_priority_admitted_first():
    """Both requests visible on tick 0 with one slot: the later-submitted
    high-priority request decodes first; ties fall back to submission
    order."""
    eng = _engine()
    lo = _req(0, 4, max_new=3, priority=0)
    hi = _req(1, 4, max_new=3, priority=5)
    sched = eng.make_scheduler([lo, hi], n_slots=1, page_size=4)
    res = {r.uid: r for r in sched.run([lo, hi])}
    assert res[1].admitted_tick < res[0].admitted_tick
    ref = {u: np.asarray(eng.generate(
        {"tokens": [lo, hi][u].inputs["tokens"]},
        max_new=3))[0].tolist() for u in (0, 1)}
    assert res[0].tokens == ref[0] and res[1].tokens == ref[1]
    tie_a, tie_b = _req(0, 4, max_new=2), _req(1, 4, max_new=2)
    sched = eng.make_scheduler([tie_a, tie_b], n_slots=1, page_size=4)
    res = {r.uid: r for r in sched.run([tie_a, tie_b])}
    assert res[0].admitted_tick < res[1].admitted_tick


def test_blocked_request_does_not_stall_queue():
    """A high-priority request whose pages don't fit yet must be skipped
    over, not block admission of requests behind it (the old scheduler
    stalled head-of-line)."""
    eng = _engine()
    # big needs ceil((8 + 8 - 1) / 4) = 4 pages; each small promises 2
    big = _req(0, 8, max_new=8, priority=9)
    smalls = [_req(i, 2, max_new=4, priority=0) for i in (1, 2)]
    # pool of 4 live pages: big fits ONLY into an empty pool
    sched = eng.make_scheduler([big] + smalls, n_slots=2, page_size=4,
                               n_pages=5, max_len=16)
    # occupy the pool so big is blocked at tick 0
    sched.submit(smalls[0])
    sched.step()
    assert sched.slots[0] is not None
    sched.submit(big)
    sched.submit(smalls[1])
    sched.step()
    # big (priority 9) heads the queue but cannot fit; small #2 must have
    # been admitted past it into the second slot
    assert any(s is not None and s.req.uid == 2 for s in sched.slots), \
        "blocked high-priority request stalled the queue"
    assert all(not (s is not None and s.req.uid == 0)
               for s in sched.slots)
    while sched.waiting or any(s is not None for s in sched.slots):
        sched.step()
    assert sched.results[0].tokens == np.asarray(eng.generate(
        {"tokens": big.inputs["tokens"]}, max_new=8))[0].tolist()
    assert sched.allocator.free_count == 4 and sched.allocator.reserved == 0


# ---------------------------------------------------------------------------
# park / restore: bit-identical host round trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_bits", [8, 4])
def test_park_restore_roundtrip_bit_identical(kv_bits):
    """Snapshot a mid-decode slot to host memory, corrupt its pool pages
    and state row on device, restore — every leaf must come back
    bit-identical (quantized payloads cross as raw bytes, no dequant)."""
    eng = _engine(kv_bits)
    reqs = [_req(0, 6, max_new=8), _req(1, 3, max_new=8)]
    sched = eng.make_scheduler(reqs, n_slots=2, page_size=4)
    for r in reqs:
        sched.submit(r)
    for _ in range(4):
        sched.step()
    s = sched.slots[0]
    assert s is not None and s.pages
    before = jax.tree_util.tree_map(np.asarray, sched.state)
    rec = eng.park_slot(sched.state, 0, s.block_pages)
    corrupted = sched.state
    for p in s.block_pages:                    # trash-page bytes over it
        corrupted = eng.copy_pool_page(corrupted, 0, p)
    restored = eng.restore_slot(corrupted, 0, s.block_pages, rec)
    after = jax.tree_util.tree_map(np.asarray, restored)
    flat_b, _ = jax.tree_util.tree_flatten(before)
    flat_a, _ = jax.tree_util.tree_flatten(after)
    for xb, xa in zip(flat_b, flat_a):
        assert xb.dtype == xa.dtype and np.array_equal(xb, xa), \
            "park/restore round trip is not bit-identical"
    with pytest.raises(ValueError, match="snapshot holds"):
        eng.restore_slot(corrupted, 0, s.block_pages[:-1], rec)


# ---------------------------------------------------------------------------
# copy-on-write guard
# ---------------------------------------------------------------------------

def test_cow_privatizes_shared_pages_without_token_drift():
    """Force the (structurally unreachable) divergent-write path: after a
    second request aliases the first's prompt pages, privatize them via
    ``_cow_from`` mid-flight — refcounts drop, the block table repoints
    at fresh copies, and the emitted tokens still match one-shot."""
    eng = _engine()
    cfg = eng.api.cfg
    shared = jax.random.randint(jax.random.PRNGKey(5), (1, 9), 0,
                                cfg.vocab).astype(jnp.int32)
    reqs = [_req(0, 0, max_new=6, tokens=shared),
            _req(1, 0, max_new=6, arrival=1, tokens=shared)]
    refs = [np.asarray(eng.generate({"tokens": shared},
                                    max_new=6))[0].tolist()] * 2
    sched = eng.make_scheduler(reqs, n_slots=2, page_size=4,
                               prefix_cache=True)
    for r in reqs:
        sched.submit(r)
    sched.step()                               # uid 0 admits + registers
    sched.step()                               # uid 1 admits with 2 hits
    follower = next(i for i, s in enumerate(sched.slots)
                    if s is not None and s.req.uid == 1)
    s = sched.slots[follower]
    assert s.n_shared == 2, "prefix hit did not alias the shared pages"
    in_use = sched.allocator.in_use
    sched._cow_from(follower, 0)
    assert s.n_shared == 0 and len(s.pages) == s.n_blocks
    assert sched.sched_stats["cow_copies"] == 2
    assert sched.allocator.in_use == in_use + 2    # private copies added
    assert not sched.validate(), sched.validate()
    while sched.waiting or any(sl is not None for sl in sched.slots):
        sched.step()
    for uid in (0, 1):
        assert sched.results[uid].tokens == refs[uid], \
            "copy-on-write perturbed decode"
    assert sched.allocator.in_use == 0
    assert sched.prefix_cache.outstanding_refs == 0
