"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Loads (or initializes) a model, optionally converts it to packed integer
serving weights (BWQ deployment), and decodes either as one static batch
(default) or as staggered requests through the continuous-batching
scheduler (``--requests``).  ``--ckpt DIR`` cold-starts straight from a
sharded training checkpoint: each QAT leaf streams from its shard files
into the serving wire format one at a time, so the dense f32 tree is
never resident (the BWQ-H deployment unit is the packed artifact).  ``--kv-bits {4,8}`` selects the
quantized-at-rest KV cache; ``--temperature``/``--top-k`` enable sampling.

Scheduler production knobs (``--requests`` + ``--page-size`` mode):
``--priority`` assigns cycling per-request priority classes,
``--overcommit`` admits past pool capacity (preempting victims to host
memory when growth runs dry), ``--prefix-cache`` shares identical prompt
prefix pages by content hash, ``--shared-prefix N`` makes the first N
prompt tokens a common system prompt across the batch, and
``--stats-out`` dumps the scheduler's cache/preemption/prefix stats as
JSON for CI smoke assertions.
"""
import argparse

import jax
import jax.numpy as jnp

from ..configs import REGISTRY
from ..models.api import build
from ..models.common import QuantConfig
from ..serve import Request, SamplingParams, ServeEngine
from ..serve.deploy import (default_deploy_bits, default_deploy_layout,
                            to_serving_params)


def _prompts(cfg, args):
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab).astype(jnp.int32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.vision_tokens, cfg.d_model)) * 0.1
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, args.prompt_len, cfg.d_model)) * 0.1
    return batch


def resolve_ckpt_dir(path: str, step: int = -1) -> str:
    """Resolve ``--ckpt`` to a concrete checkpoint directory: either the
    path itself (it holds a META) or a ``step_<N>`` child of a
    CheckpointManager directory (``step`` = -1 picks the latest)."""
    import os
    if os.path.exists(os.path.join(path, "META")):
        return path
    if step < 0:
        from ..ckpt.checkpoint import CheckpointManager
        latest = CheckpointManager(path).latest_step()
        if latest is None:
            raise SystemExit(f"--ckpt {path}: no step_N checkpoints found")
        step = latest
    out = os.path.join(path, f"step_{step}")
    if not os.path.exists(os.path.join(out, "META")):
        raise SystemExit(f"--ckpt: {out} is not a checkpoint directory")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(REGISTRY))
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--no-tiny", dest="tiny", action="store_false")
    ap.add_argument("--deploy-bits", type=int, default=0,
                    choices=[0, 4, 8], help="0 = QAT weights")
    ap.add_argument("--kv-bits", type=int, default=32, choices=[4, 8, 32],
                    help="quantized-at-rest KV cache precision")
    ap.add_argument("--backend", default="dense",
                    choices=["dense", "pallas", "ref", "bitplane"],
                    help="matmul execution backend for deployed weights "
                         "(non-dense implies --deploy-bits 8 unless set; "
                         "bitplane deploys the plane-sliced layout)")
    ap.add_argument("--attn-backend", default="gather",
                    choices=["gather", "fused", "ref"],
                    help="decode-attention read side: gather materializes "
                         "the contiguous KV view per step; fused runs the "
                         "Pallas paged-attention kernel over the stored "
                         "(quantized) cache; ref is its jnp oracle")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0,
                    help="base sampling seed (each request adds its uid)")
    ap.add_argument("--requests", action="store_true",
                    help="feed the batch as staggered requests through the "
                         "continuous-batching scheduler")
    ap.add_argument("--n-slots", type=int, default=0,
                    help="decode slots for --requests (0 = batch size)")
    ap.add_argument("--arrival-gap", type=int, default=2,
                    help="ticks between request arrivals in --requests mode")
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged KV cache: tokens per page (0 = contiguous "
                         "fixed-width slots); --requests mode only")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="page-pool capacity (0 = worst case + trash page)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="insert prompts in chunks this wide, interleaved "
                         "with decode (0 = monolithic prefill)")
    ap.add_argument("--priority", default="",
                    help="comma-separated priority classes cycled over the "
                         "batch, e.g. '0,1' alternates low/high ('' = all "
                         "equal); higher admits first, parks last")
    ap.add_argument("--overcommit", type=float, default=1.0,
                    help="admit worst-case page reservations up to this "
                         "multiple of pool capacity; > 1 preempts (parks "
                         "to host memory) lowest-priority victims when "
                         "decode growth exhausts the free list")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="content-addressed prompt-prefix page sharing: "
                         "identical full prompt pages are held once, "
                         "refcounted, across concurrent requests")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="make the first N prompt tokens identical across "
                         "the batch (a shared system prompt) to exercise "
                         "--prefix-cache")
    ap.add_argument("--stats-out", default="",
                    help="write the scheduler stats JSON (cache report + "
                         "preemption / prefix-hit counters) to this file")
    ap.add_argument("--lint", action="store_true",
                    help="run the static serving-graph lint before serving "
                         "and abort if it reports errors")
    ap.add_argument("--autotune-budget-bytes", type=int, default=0,
                    help="search per-block bit-widths under this "
                         "weight-stream-bytes budget before serving "
                         "(bitplane layout only; 0 = off)")
    ap.add_argument("--speculate-planes", type=int, default=0,
                    help="self-speculative decoding: draft with only the "
                         "top-k live planes of each block (0 = off)")
    ap.add_argument("--draft-gamma", type=int, default=4,
                    help="draft tokens proposed per speculative round")
    ap.add_argument("--ckpt", default="",
                    help="cold-start from a checkpoint directory (a "
                         "CheckpointManager dir or a single step_N dir): "
                         "weights stream shard-by-shard straight into the "
                         "serving wire format, never materializing the "
                         "dense f32 tree")
    ap.add_argument("--ckpt-step", type=int, default=-1,
                    help="with --ckpt on a manager dir: the step to load "
                         "(-1 = latest)")
    args = ap.parse_args()

    cfg = REGISTRY[args.arch]
    if args.tiny:
        cfg = cfg.tiny(dtype="float32")
    cfg = cfg.with_quant(QuantConfig(mode="fake", n_bits=8, act_bits=8))
    api = build(cfg)
    args.deploy_bits = default_deploy_bits(args.backend, args.deploy_bits)
    if args.ckpt:
        path = resolve_ckpt_dir(args.ckpt, args.ckpt_step)
        layout = default_deploy_layout(args.backend)
        stats = {}
        params = to_serving_params(path, args.deploy_bits or 8,
                                   layout=layout,
                                   template=api.abstract_params(),
                                   stats=stats)
        print(f"cold-start: {path} -> {layout} "
              f"int{args.deploy_bits or 8} serving weights "
              f"(peak {stats['peak_host_bytes']} B host vs "
              f"{stats['dense_tree_bytes']} B dense tree)")
    else:
        params = api.init(jax.random.PRNGKey(0))
        if args.deploy_bits:
            layout = default_deploy_layout(args.backend)
            params = to_serving_params(params, args.deploy_bits,
                                       layout=layout)
            print(f"deployed: {layout} int{args.deploy_bits} "
                  f"serving weights")

    batch = _prompts(cfg, args)
    if args.shared_prefix:
        import numpy as np
        toks = np.array(batch["tokens"])
        toks[:, :args.shared_prefix] = toks[0, :args.shared_prefix]
        batch["tokens"] = jnp.asarray(toks)

    if args.autotune_budget_bytes:
        from ..serve.autotune import autotune_params
        from ..serve.deploy import weight_stream_bytes
        before = weight_stream_bytes(params)
        alloc = autotune_params(api, params, args.autotune_budget_bytes,
                                batch=batch)
        params = alloc.params
        print(f"autotuned: {before} -> {alloc.total_bytes} B/step under a "
              f"{alloc.budget_bytes} B budget "
              f"({alloc.steps_taken}/{alloc.steps_available} plane "
              f"increments kept); gate {alloc.gate}")

    eng = ServeEngine(api, params, kv_quant_bits=args.kv_bits,
                      backend=args.backend, attn_backend=args.attn_backend,
                      page_size=args.page_size,
                      n_pages=args.n_pages or None,
                      prefill_chunk=args.prefill_chunk,
                      overcommit=args.overcommit,
                      prefix_cache=args.prefix_cache,
                      speculate_planes=args.speculate_planes,
                      draft_gamma=args.draft_gamma)

    if args.lint:
        from ..analysis import lint_engine
        report = lint_engine(
            eng, prompt_len=args.prompt_len,
            n_slots=args.n_slots or args.batch, max_new=args.max_new,
            autotune_budget_bytes=args.autotune_budget_bytes or None)
        print(report.format(max_info=0))
        if not report.ok:
            raise SystemExit("serving-graph lint failed; aborting launch")

    if args.requests:
        prios = [int(p) for p in args.priority.split(",") if p != ""] or [0]
        reqs = [Request(uid=i,
                        inputs={k: v[i:i + 1] for k, v in batch.items()},
                        sampling=SamplingParams(
                            max_new_tokens=args.max_new,
                            temperature=args.temperature,
                            top_k=args.top_k, eos_id=args.eos_id,
                            seed=args.seed + i,
                            priority=prios[i % len(prios)]),
                        arrival=i * args.arrival_gap)
                for i in range(args.batch)]
        sched = eng.make_scheduler(reqs, n_slots=args.n_slots or args.batch)
        results = sched.run(reqs)
        for r in results:
            print(f"[{r.uid}] arrived@{reqs[r.uid].arrival} "
                  f"admitted@{r.admitted_tick} done@{r.finished_tick} "
                  f"({r.finish_reason}): {r.tokens}")
        if args.page_size or args.stats_out:
            import json
            stats = sched.cache_report()
            print(json.dumps(stats))
            if args.stats_out:
                findings = sched.validate()
                stats["contract_findings"] = [f.format() for f in findings]
                with open(args.stats_out, "w") as f:
                    json.dump(stats, f, indent=2)
        if args.speculate_planes:
            print(f"speculative: {sched.spec_stats}")
        return

    key = jax.random.PRNGKey(args.seed) if args.temperature > 0 else None
    out = eng.generate(batch, max_new=args.max_new,
                       greedy=args.temperature <= 0, key=key,
                       temperature=args.temperature, top_k=args.top_k)
    for i, row in enumerate(out.tolist()):
        print(f"[{i}] {row}")


if __name__ == "__main__":
    main()
