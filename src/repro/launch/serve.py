"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Loads (or initializes) a model, optionally converts it to packed integer
serving weights (BWQ deployment), and runs batched greedy decoding.
"""
import argparse

import jax
import jax.numpy as jnp

from ..configs import REGISTRY
from ..models.api import build
from ..models.common import QuantConfig
from ..serve import ServeEngine
from ..serve.deploy import to_serving_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(REGISTRY))
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--no-tiny", dest="tiny", action="store_false")
    ap.add_argument("--deploy-bits", type=int, default=0,
                    choices=[0, 4, 8], help="0 = QAT weights")
    ap.add_argument("--kv-bits", type=int, default=32, choices=[8, 32])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = REGISTRY[args.arch]
    if args.tiny:
        cfg = cfg.tiny(dtype="float32")
    cfg = cfg.with_quant(QuantConfig(mode="fake", n_bits=8, act_bits=8))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    if args.deploy_bits:
        params = to_serving_params(params, args.deploy_bits)
        print(f"deployed: packed int{args.deploy_bits} serving weights")

    eng = ServeEngine(api, params, kv_quant_bits=args.kv_bits)
    prompts = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab).astype(jnp.int32)}
    if cfg.family == "vlm":
        prompts["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.vision_tokens, cfg.d_model)) * 0.1
    if cfg.is_encdec:
        prompts["frames"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, args.prompt_len, cfg.d_model)) * 0.1
    out = eng.generate(prompts, max_new=args.max_new)
    for i, row in enumerate(out.tolist()):
        print(f"[{i}] {row}")


if __name__ == "__main__":
    main()
