"""Production mesh builders.

A function, not a module constant: importing this module never touches jax
device state, so tests/benches keep their single-device world.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    # axis_types only exists on newer jax; older versions are Auto-only.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    return _mesh(tuple(shape), tuple(axes))
