"""Entry points: train / dryrun / serve launchers and mesh builders."""
