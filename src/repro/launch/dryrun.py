import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * 16x16 single-pod mesh (256 chips)  — roofline source
  * 2x16x16 multi-pod mesh (512 chips) — proves the 'pod' axis shards
For each cell we lower the right step (train_step / prefill / decode),
compile, and record memory_analysis, cost_analysis and the collective
schedule into a JSON artifact consumed by benchmarks/roofline.py and
EXPERIMENTS.md.

NOTE: the XLA_FLAGS line above MUST run before any jax import — jax locks
the device count at first init.  Do not set it globally.
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs import REGISTRY
from ..configs.base import ModelConfig, ShapeCell, cells_for
from ..dist.hlo_analysis import (collective_stats, dominant_term,
                                 roofline_terms)
from ..dist.sharding import (batch_pspecs, cache_pspecs, padded_shape,
                             param_pspecs, unpad_leaf, use_mesh)
from ..models import moe as moe_mod
from ..models.api import build
from ..optim.optimizers import adamw
from ..train.state import TrainState
from ..train.step import (freeze_mask, microbatched_value_and_grad,
                          quant_reg_loss)
from .mesh import make_production_mesh


def _shardings(mesh, pspec_tree):
    return jax.tree_util.tree_map(
        lambda ps: jax.sharding.NamedSharding(mesh, ps), pspec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def _pad_abstract(tree, mesh):
    """Padded-sharding boundary for abstract lowering: fit each leaf's
    (padded-mode) spec, grow the ShapeDtypeStruct to the padded shape so
    ``in_shardings`` stay divisible, and remember the true shapes for the
    in-graph unpad.  Returns (padded_tree, spec_tree, true_shapes)."""
    from jax.sharding import PartitionSpec as P
    with use_mesh(mesh):
        specs = param_pspecs(tree)
    flat, treedef = jax.tree_util.tree_flatten(tree)
    sflat = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    padded = jax.tree_util.tree_unflatten(treedef, [
        jax.ShapeDtypeStruct(padded_shape(s, x.shape, mesh), x.dtype)
        for x, s in zip(flat, sflat)])
    return padded, specs, [tuple(x.shape) for x in flat]


def _unpadding(fn, true_shapes):
    """Wrap a step fn so its first arg (params) is sliced back to the true
    shapes before the model sees it — the consumer mask of padded
    placement, identical to ``ServeEngine._unpad_params``."""
    def wrapped(params, *args, **kwargs):
        flat, treedef = jax.tree_util.tree_flatten(params)
        params = jax.tree_util.tree_unflatten(
            treedef, [unpad_leaf(x, s)
                      for x, s in zip(flat, true_shapes)])
        return fn(params, *args, **kwargs)
    return wrapped


def _cost_dict(compiled) -> Dict[str, float]:
    """compiled.cost_analysis() returns a dict on new jax, [dict] on old."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def _model_flops_estimate(cfg: ModelConfig, cell: ShapeCell) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (fwd-only)."""
    d, L = cfg.d_model, cfg.n_layers
    dh = cfg.head_dim
    attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads + cfg.n_heads) * dh
    if cfg.n_experts:
        ff_active = 3 * d * cfg.d_ff * (cfg.top_k + cfg.n_shared_experts)
    elif cfg.family == "ssm":
        ff_active = 5 * d * d + 2 * d * cfg.d_ff     # time mix + channel mix
        attn = 0
    elif cfg.family == "hybrid":
        di = cfg.ssm_expand * d
        ff_active = 2 * d * di + 2 * d * cfg.ssm_state + d * di
        attn = 0
    else:
        mult = 3 if cfg.mlp_kind == "swiglu" else 2
        ff_active = mult * d * cfg.d_ff
    n_active = L * (attn + ff_active)
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        blocks = cfg.n_layers // cfg.hybrid_attn_every
        n_active += blocks * (
            2 * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * dh   # 2d-in qkv
            + cfg.n_heads * dh * d                            # wo
            + 3 * d * cfg.d_ff)                               # shared mlp
    if cfg.is_encdec and cell.kind != "decode":
        n_active *= 2            # encoder stack of similar size
    n_active += 2 * cfg.vocab * d    # embed + lm head
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    factor = 6.0 if cell.kind == "train" else 2.0
    return factor * n_active * tokens


def _lower_once(cfg: ModelConfig, cell: ShapeCell, mesh, microbatches: int,
                deploy_bits: int = 0):
    """Build + lower + compile one step; returns (compiled, timings).

    ``deploy_bits`` > 0 lowers decode/prefill against packed integer
    serving weights (EXPERIMENTS.md §Perf beyond-paper path)."""
    moe_mod.GROUPED_IMPL["impl"] = "capacity"   # at-scale MoE path
    api = build(cfg)
    t0 = time.time()
    with use_mesh(mesh):
        aparams = api.abstract_params()
        if deploy_bits and cell.kind != "train":
            from ..serve.deploy import to_serving_params
            aparams = jax.eval_shape(
                lambda p: to_serving_params(p, deploy_bits), aparams)
        aparams_p, p_specs, p_shapes = _pad_abstract(aparams, mesh)
        p_sh = _shardings(mesh, p_specs)
        if cell.kind == "train":
            opt = adamw()
            astate = jax.eval_shape(
                lambda p: TrainState.create(p, opt), aparams)
            # the train state is donated and round-trips through the jit:
            # it cannot carry placement padding, so fit with the drop rule
            s_sh = _shardings(mesh, param_pspecs(astate, pad=False))
            batch = api.train_batch_spec(cell)
            b_sh = _shardings(mesh, batch_pspecs(batch))

            def train_step(state, b):
                def total(params, bb):
                    loss, metrics = api.loss(params, bb)
                    return loss + quant_reg_loss(params, state.alpha), metrics
                vg = microbatched_value_and_grad(total, microbatches)
                (loss, _), grads = vg(state.params, b)
                grads = freeze_mask(grads)
                new_p, new_o = opt.update(grads, state.opt_state,
                                          state.params, 1e-3)
                return TrainState(step=state.step + 1, params=new_p,
                                  opt_state=new_o, alpha=state.alpha), loss

            jitted = jax.jit(train_step, in_shardings=(s_sh, b_sh),
                             out_shardings=(s_sh, None), donate_argnums=(0,))
            lowered = jitted.lower(astate, batch)
        elif cell.kind == "prefill":
            batch = api.train_batch_spec(cell)
            batch.pop("labels", None)
            b_sh = _shardings(mesh, batch_pspecs(batch))
            jitted = jax.jit(_unpadding(api.prefill, p_shapes),
                             in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(aparams_p, batch)
        else:  # decode
            state_spec = api.decode_state_spec(cell)
            # donated decode state round-trips: fit with the drop rule
            c_sh = _shardings(mesh, cache_pspecs(state_spec,
                                                 cell.global_batch,
                                                 pad=False))
            tok = api.decode_token_spec(cell)
            t_sh = _shardings(mesh, batch_pspecs({"t": tok}))["t"]
            idx = jax.ShapeDtypeStruct((), jnp.int32)
            i_sh = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
            jitted = jax.jit(_unpadding(api.decode_step, p_shapes),
                             in_shardings=(p_sh, t_sh, c_sh, i_sh),
                             out_shardings=(None, c_sh), donate_argnums=(2,))
            lowered = jitted.lower(aparams_p, tok, state_spec, idx)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, t_lower, t_compile


def _calibrated_costs(cfg: ModelConfig, cell: ShapeCell, mesh,
                      microbatches: int,
                      deploy_bits: int = 0) -> Dict[str, float]:
    """Exact per-device FLOP/byte/collective totals via unrolled smalls.

    XLA cost_analysis counts each scan body ONCE, so the scanned lowering
    undercounts by the trip counts.  We lower tiny UNROLLED configs
    (scan_layers=False, single microbatch, un-chunked SSM, dense attention)
    at 1 / 2 layers (hybrids: one attn period + one extra), solve the
    linear model cost(L) = base + L * per_layer, and scale to the full
    depth and microbatch count.  This matches what the scanned program
    executes because every scan body is shape-identical across trips.
    """
    from ..models import attention as attn_mod

    n_mb = microbatches if cell.kind == "train" else 1
    small_cell = dataclasses.replace(
        cell, global_batch=max(1, cell.global_batch // max(n_mb, 1)))

    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        p = cfg.hybrid_attn_every
        points = [p, p + 1, 2 * p]
    else:
        points = [1, 2]

    results = []
    old_opts = dict(attn_mod.ATTN_OPTS)
    attn_mod.ATTN_OPTS["min_elems"] = 1 << 62     # force dense (no scan)
    try:
        for L in points:
            over = dict(n_layers=L, scan_layers=False,
                        ssm_chunk=1 << 30, rwkv_chunk=1 << 30)
            if cfg.is_encdec:
                over["enc_layers"] = L
            ccfg = dataclasses.replace(cfg, **over)
            compiled, _, _ = _lower_once(ccfg, small_cell, mesh,
                                         microbatches=1,
                                         deploy_bits=deploy_bits)
            ca = _cost_dict(compiled)
            colls = collective_stats(compiled.as_text())
            results.append(dict(flops=float(ca.get("flops", 0.0)),
                                bytes=float(ca.get("bytes accessed", 0.0)),
                                coll=colls.total_bytes))
    finally:
        attn_mod.ATTN_OPTS.update(old_opts)

    def solve(key):
        if len(points) == 2:
            per_layer = results[1][key] - results[0][key]
            base = results[0][key] - points[0] * per_layer
            total = base + cfg.n_layers * per_layer
        else:                      # hybrid: f(p), f(p+1), f(2p)
            f_p, f_p1, f_2p = (r[key] for r in results)
            mamba = f_p1 - f_p
            period = f_2p - f_p            # p mamba + 1 shared block
            base = f_p - period
            n_super = cfg.n_layers // cfg.hybrid_attn_every
            tail = cfg.n_layers - n_super * cfg.hybrid_attn_every
            total = base + n_super * period + tail * mamba
        return max(total, 0.0) * n_mb

    return dict(flops=solve("flops"), bytes=solve("bytes"),
                coll=solve("coll"))


def lower_cell(cfg: ModelConfig, cell: ShapeCell, mesh,
               include_text: bool = False, microbatches: int = 0,
               calibrate: bool = True,
               deploy_bits: int = 0) -> Dict[str, Any]:
    if microbatches == 0 and cell.kind == "train":
        # default: ~2 sequences per device per microbatch
        dp = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                dp *= mesh.shape[a]
        per_dev = max(1, cell.global_batch // dp)
        microbatches = max(1, per_dev)       # ~1 sequence/device/microbatch
        while cell.global_batch % microbatches:
            microbatches -= 1

    compiled, t_lower, t_compile = _lower_once(cfg, cell, mesh, microbatches,
                                               deploy_bits=deploy_bits)
    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    txt = compiled.as_text()
    colls = collective_stats(txt)
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))

    if calibrate:
        cal = _calibrated_costs(cfg, cell, mesh, microbatches, deploy_bits)
        flops, bytes_acc, coll_bytes = cal["flops"], cal["bytes"], cal["coll"]
    else:
        flops, bytes_acc, coll_bytes = raw_flops, raw_bytes, colls.total_bytes

    terms = roofline_terms(flops, bytes_acc, coll_bytes)
    model_flops = _model_flops_estimate(cfg, cell)
    chips = mesh.devices.size
    rec = dict(
        arch=cfg.name, cell=cell.name, kind=cell.kind,
        mesh=list(mesh.shape.values()), chips=chips,
        seq_len=cell.seq_len, global_batch=cell.global_batch,
        microbatches=microbatches,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        per_device=dict(
            flops=flops, bytes_accessed=bytes_acc,
            collective_bytes=coll_bytes,
            raw_scan_flops=raw_flops, raw_scan_bytes=raw_bytes,
            raw_scan_collective_bytes=colls.total_bytes,
            argument_bytes=int(mem.argument_size_in_bytes),
            output_bytes=int(mem.output_size_in_bytes),
            temp_bytes=int(mem.temp_size_in_bytes),
            peak_hbm_gib=round((mem.argument_size_in_bytes
                                + mem.temp_size_in_bytes
                                + mem.output_size_in_bytes) / 2**30, 3),
        ),
        collectives=dict(counts=colls.counts, bytes=colls.bytes_by_op),
        roofline=terms,
        dominant=dominant_term(terms),
        model_flops_global=model_flops,
        hlo_flops_global=flops * chips,
        useful_flops_frac=(model_flops / (flops * chips)
                           if flops else 0.0),
    )
    if include_text:
        rec["hlo_text"] = txt
    return rec


def run_cells(arch_names, cell_names, multi_pod: bool, out_dir: str,
              skip_existing: bool = True) -> None:
    mesh = make_production_mesh(multi_pod=multi_pod)
    tag = "multipod" if multi_pod else "singlepod"
    os.makedirs(out_dir, exist_ok=True)
    for name in arch_names:
        cfg = REGISTRY[name]
        for cell in cells_for(cfg):
            if cell_names and cell.name not in cell_names:
                continue
            out = os.path.join(out_dir, f"{tag}__{name}__{cell.name}.json")
            if skip_existing and os.path.exists(out):
                print(f"[skip] {out}")
                continue
            print(f"[dryrun] {tag} {name} {cell.name} ...", flush=True)
            try:
                # roofline calibration is single-pod only (assignment);
                # the multi-pod pass proves the 'pod' axis shards.
                rec = lower_cell(cfg, cell, mesh, calibrate=not multi_pod)
                with open(out, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"  ok: dominant={rec['dominant']} "
                      f"hbm/dev={rec['per_device']['peak_hbm_gib']}GiB "
                      f"compile={rec['compile_s']}s", flush=True)
            except Exception as e:
                err = os.path.join(out_dir,
                                   f"{tag}__{name}__{cell.name}.ERROR")
                with open(err, "w") as f:
                    f.write(traceback.format_exc())
                print(f"  FAIL: {type(e).__name__}: {e}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--cell", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    archs = sorted(REGISTRY) if args.arch == "all" else args.arch.split(",")
    cells = None if args.cell == "all" else args.cell.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for mp in meshes:
        run_cells(archs, cells, mp, args.out, skip_existing=not args.force)


if __name__ == "__main__":
    main()
