"""Serving-graph lint CLI: ``python -m repro.launch.lint --arch <id>
--backend <b>`` — the CI gate behind the ``lint-serving`` job.

Builds the same engine ``launch.serve`` would (tiny config, fake-quant
QAT, deployed serving weights in the backend's native layout), then runs
every static pass from ``repro.analysis`` — contract validation, jaxpr
graph lint, compile-footprint census, and (with ``--mesh`` /
``--production-mesh``) the sharding lint against a deviceless mesh
stand-in.  Nothing compiles or executes.  Exit code 1 iff the report
carries errors.
"""
import argparse
import sys

import jax

from ..analysis import (ShapeOnlyMesh, lint_engine, production_mesh_shape,
                        validate_checkpoint)
from ..configs import REGISTRY
from ..models.api import build
from ..models.common import QuantConfig
from ..serve import ServeEngine
from ..serve.deploy import (default_deploy_bits, default_deploy_layout,
                            to_serving_params)


def build_engine(arch: str, backend: str, deploy_bits: int = 0,
                 layout: str = "", kv_bits: int = 32, page_size: int = 0,
                 prefill_chunk: int = 0, tiny: bool = True,
                 autotune_budget_bytes: int = 0,
                 speculate_planes: int = 0,
                 attn_backend: str = "gather") -> ServeEngine:
    """The serving stack exactly as ``launch.serve`` assembles it.

    ``autotune_budget_bytes`` runs the (weight-only) greedy budget search
    over the deployed tree before building the engine, so the AT1
    contract can be linted against a genuinely autotuned assignment."""
    cfg = REGISTRY[arch]
    if tiny:
        cfg = cfg.tiny(dtype="float32")
    cfg = cfg.with_quant(QuantConfig(mode="fake", n_bits=8, act_bits=8))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    deploy_bits = default_deploy_bits(backend, deploy_bits)
    if deploy_bits:
        params = to_serving_params(
            params, deploy_bits,
            layout=layout or default_deploy_layout(backend))
    if autotune_budget_bytes:
        from ..serve.autotune import greedy_allocate, sensitivity_tree
        params = greedy_allocate(params, sensitivity_tree(params),
                                 autotune_budget_bytes).params
    return ServeEngine(api, params, kv_quant_bits=kv_bits, backend=backend,
                       attn_backend=attn_backend, page_size=page_size,
                       prefill_chunk=prefill_chunk,
                       speculate_planes=speculate_planes)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(REGISTRY))
    ap.add_argument("--backend", default="dense",
                    choices=["dense", "pallas", "ref", "bitplane"])
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--no-tiny", dest="tiny", action="store_false")
    ap.add_argument("--deploy-bits", type=int, default=0,
                    choices=[0, 4, 8],
                    help="0 = backend default (int8 for packed backends)")
    ap.add_argument("--layout", default="",
                    choices=["", "packed", "bitplane"],
                    help="serving wire format (default: backend's native)")
    ap.add_argument("--kv-bits", type=int, default=32, choices=[4, 8, 32])
    ap.add_argument("--attn-backend", default="gather",
                    choices=["gather", "fused", "ref"],
                    help="decode-attention read side (fused = Pallas "
                         "paged-attention kernel)")
    ap.add_argument("--page-size", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=0)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--budget", type=int, default=8,
                    help="compile-signature budget (footprint pass)")
    ap.add_argument("--mesh", default="",
                    help="lint sharding against 'AXISxAXIS' sizes, e.g. "
                         "'data=2,model=4' (deviceless stand-in)")
    ap.add_argument("--production-mesh", action="store_true",
                    help="lint sharding against the 16x16 production mesh")
    ap.add_argument("--multi-pod", action="store_true",
                    help="with --production-mesh: the 2x16x16 pod mesh")
    ap.add_argument("--json", action="store_true", dest="as_json")
    ap.add_argument("--max-info", type=int, default=None,
                    help="truncate info findings in text output")
    ap.add_argument("--autotune-budget-bytes", type=int, default=0,
                    help="run the greedy budget search before linting and "
                         "check the AT1 contract against that budget")
    ap.add_argument("--speculate-planes", type=int, default=0,
                    help="build the top-k draft tree and check the AT2 "
                         "contract against the deployed tree")
    ap.add_argument("--ckpt", default="",
                    help="additionally validate a checkpoint directory's "
                         "shard manifests (CK1-CK3 contracts)")
    args = ap.parse_args(argv)

    engine = build_engine(args.arch, args.backend, args.deploy_bits,
                          args.layout, args.kv_bits, args.page_size,
                          args.prefill_chunk, args.tiny,
                          autotune_budget_bytes=args.autotune_budget_bytes,
                          speculate_planes=args.speculate_planes,
                          attn_backend=args.attn_backend)
    mesh = None
    if args.production_mesh:
        mesh = ShapeOnlyMesh(production_mesh_shape(args.multi_pod))
    elif args.mesh:
        mesh = ShapeOnlyMesh({
            kv.split("=")[0].strip(): int(kv.split("=")[1])
            for kv in args.mesh.split(",")})
    report = lint_engine(engine, prompt_len=args.prompt_len,
                         n_slots=args.n_slots, max_new=args.max_new,
                         budget=args.budget, mesh=mesh,
                         autotune_budget_bytes=(args.autotune_budget_bytes
                                                or None))
    if args.ckpt:
        report.extend(validate_checkpoint(args.ckpt))
    if args.as_json:
        print(report.to_json())
    else:
        print(report.format(max_info=args.max_info))
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
