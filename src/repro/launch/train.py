"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Single-process CPU runs use reduced configs by default (--tiny); on a real
TPU slice the same entrypoint drives the full config under the production
mesh (jax.distributed initialization is environment-driven).
"""
import argparse

import jax

from ..configs import REGISTRY
from ..data import make_lm_pipeline
from ..dist.sharding import use_mesh
from ..models.api import build
from ..models.common import QuantConfig
from ..optim import adamw, cosine_schedule
from ..train import Trainer, TrainerConfig
from .mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(REGISTRY))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--no-tiny", dest="tiny", action="store_false")
    ap.add_argument("--quant-mode", default="fake",
                    choices=["none", "bitplane", "fake"])
    ap.add_argument("--act-bits", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--requant-every", type=int, default=50)
    ap.add_argument("--delta-alpha", type=float, default=1e-3)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"])
    args = ap.parse_args()

    cfg = REGISTRY[args.arch]
    if args.tiny:
        cfg = cfg.tiny(dtype="float32")
    cfg = cfg.with_quant(QuantConfig(mode=args.quant_mode, n_bits=8,
                                     act_bits=args.act_bits)) \
        if args.quant_mode != "none" else \
        cfg.with_quant(QuantConfig(mode="none"))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))

    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    with use_mesh(mesh):
        trainer = Trainer(
            lambda p, b: api.loss(p, b), adamw(weight_decay=0.0),
            cosine_schedule(2e-3, args.steps), params,
            TrainerConfig(total_steps=args.steps,
                          ckpt_every=max(args.steps // 4, 1)
                          if args.ckpt_dir else 0,
                          ckpt_dir=args.ckpt_dir,
                          log_every=max(args.steps // 10, 1),
                          requant_interval=args.requant_every,
                          alpha_round_steps=args.requant_every,
                          delta_alpha=args.delta_alpha))
        resumed = trainer.try_restore()
        data = make_lm_pipeline(cfg, args.seq, args.batch, start_step=resumed)
        trainer.run(data, steps=args.steps)
    for h in trainer.history:
        print(f"step {h['step']:6d} ce={h['ce']:.4f} "
              f"bits={h['avg_bitwidth']:.2f} comp={h['compression_x']:.1f}x")


if __name__ == "__main__":
    main()
