"""Shared building blocks: quantizable Dense, norms, embeddings.

Params are plain nested dicts.  Weight matrices may be stored as
``QuantizedTensor`` (paper-faithful bit planes), ``FakeQuantTensor``
(memory-scalable BWQ mode) or raw arrays; ``materialize`` converts a whole
param tree to plain weights once per step (outside the layer scan) so the
layer code only ever sees arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..core.bitrep import QuantizedTensor, compose, from_float
from ..core.blocking import BlockingSpec
from ..core.fakequant import FakeQuantTensor, fq_compose, fq_from_float
from ..core.pact import pact_sym_quant


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    mode: str = "none"            # 'none' | 'bitplane' | 'fake'
    n_bits: int = 8
    wb_rows: int = 9
    wb_cols: int = 8
    per_block_scale: bool = False  # paper-faithful: per-layer scale
    act_bits: int = 32            # 32 => no activation quantization
    pact_init: float = 6.0
    quantize_embeddings: bool = False

    @property
    def spec(self) -> BlockingSpec:
        return BlockingSpec(self.wb_rows, self.wb_cols)

    @property
    def enabled(self) -> bool:
        return self.mode != "none"


NO_QUANT = QuantConfig()


def make_weight(key, shape, qc: QuantConfig, scale: float = 1.0,
                dtype=jnp.float32, quantize: bool = True) -> Any:
    """Initialize one (possibly stacked) weight matrix (..., K, N)."""
    fan_in = shape[-2]
    w = jax.random.normal(key, shape, dtype) * (scale / jnp.sqrt(fan_in))
    if not quantize or not qc.enabled:
        return w
    if qc.mode == "bitplane":
        return from_float(w, qc.n_bits, qc.spec,
                          per_block_scale=qc.per_block_scale)
    if qc.mode == "fake":
        return fq_from_float(w, qc.n_bits, qc.spec)
    raise ValueError(qc.mode)


def _is_quant(x) -> bool:
    from ..serve.deploy import ServingWeight
    return isinstance(x, (QuantizedTensor, FakeQuantTensor, ServingWeight))


def materialize(params: Any, dtype=None) -> Any:
    """Quantized leaves -> plain weight arrays (done once, pre-scan)."""
    from ..serve.deploy import ServingWeight, serving_compose

    def conv(x):
        if isinstance(x, QuantizedTensor):
            return compose(x, dtype)
        if isinstance(x, FakeQuantTensor):
            return fq_compose(x, dtype)
        if isinstance(x, ServingWeight):
            return serving_compose(x, dtype or jnp.bfloat16)
        if dtype is not None and isinstance(x, jnp.ndarray) \
                and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(conv, params, is_leaf=_is_quant)


def act_quant(x: jnp.ndarray, beta: Optional[jnp.ndarray],
              qc: QuantConfig) -> jnp.ndarray:
    """Symmetric PACT activation quantization in front of a quantized matmul."""
    if not qc.enabled or qc.act_bits >= 32 or beta is None:
        return x
    return pact_sym_quant(x, beta.astype(x.dtype), qc.act_bits)


def make_beta(qc: QuantConfig, dtype=jnp.float32):
    return jnp.asarray(qc.pact_init, dtype) if qc.enabled and qc.act_bits < 32 \
        else None


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return jax.random.normal(key, (vocab, d), dtype) * 0.02


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap) if cap and cap > 0 else x
