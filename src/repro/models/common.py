"""Shared building blocks: quantizable Dense, norms, embeddings — and the
quantized-linear dispatch layer.

Params are plain nested dicts.  Weight matrices may be stored as
``QuantizedTensor`` (paper-faithful bit planes), ``FakeQuantTensor``
(memory-scalable BWQ mode), ``ServingWeight`` (deployed packed integers),
``BitplaneServingWeight`` (deployed 1-bit planes) or raw arrays.  Layer
code never dequantizes a weight itself: every ``x @ W`` goes through
:func:`qmatmul`, which dispatches on the weight representation and the
active execution backend:

* ``dense``    — dequantize the leaf in-graph and run a plain ``jnp`` dot
  (works for every representation; the only backend that training uses);
* ``pallas``   — stream the deployed leaf through its Pallas kernel
  (``packed_matmul`` for ServingWeight, ``bitplane_matmul`` for
  BitplaneServingWeight; interpret mode off-TPU), so the compiled
  program never holds a dequantized weight;
* ``ref``      — the pure-jnp kernel oracle of whichever layout the leaf
  carries (``kernels/ref.py``), for cross-checking;
* ``bitplane`` — the paper's precision-aware OU mapping on the hot path:
  BitplaneServingWeight leaves run through the ``bitplane_matmul`` Pallas
  kernel (per-block plane occupancy = streamed bytes); other
  representations fall back to the dense dequant dot.

The backend is selected per call (``backend=``), or ambiently with
``matmul_backend("pallas")`` — the serving engine wraps its jitted
prefill/decode in that context.  ``prepare_params`` is the once-per-step
tree prep (cast plain floats, compose bit-plane tensors that cannot ride a
layer scan); packed representations stay packed until qmatmul consumes
them one layer at a time inside the scan.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..core.bitrep import QuantizedTensor, compose, from_float
from ..core.blocking import BlockingSpec
from ..core.fakequant import FakeQuantTensor, fq_compose, fq_from_float
from ..core.pact import pact_sym_quant

MATMUL_BACKENDS = ("dense", "pallas", "ref", "bitplane")
_BACKEND_STACK = ["dense"]


@contextlib.contextmanager
def matmul_backend(name: str):
    """Ambient execution backend for :func:`qmatmul` (trace-time)."""
    if name not in MATMUL_BACKENDS:
        raise ValueError(f"unknown matmul backend {name!r}; "
                         f"choose from {MATMUL_BACKENDS}")
    _BACKEND_STACK.append(name)
    try:
        yield
    finally:
        _BACKEND_STACK.pop()


def current_matmul_backend() -> str:
    return _BACKEND_STACK[-1]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    mode: str = "none"            # 'none' | 'bitplane' | 'fake'
    n_bits: int = 8
    wb_rows: int = 9
    wb_cols: int = 8
    per_block_scale: bool = False  # paper-faithful: per-layer scale
    act_bits: int = 32            # 32 => no activation quantization
    pact_init: float = 6.0
    quantize_embeddings: bool = False

    @property
    def spec(self) -> BlockingSpec:
        return BlockingSpec(self.wb_rows, self.wb_cols)

    @property
    def enabled(self) -> bool:
        return self.mode != "none"


NO_QUANT = QuantConfig()


def make_weight(key, shape, qc: QuantConfig, scale: float = 1.0,
                dtype=jnp.float32, quantize: bool = True) -> Any:
    """Initialize one (possibly stacked) weight matrix (..., K, N)."""
    fan_in = shape[-2]
    w = jax.random.normal(key, shape, dtype) * (scale / jnp.sqrt(fan_in))
    if not quantize or not qc.enabled:
        return w
    if qc.mode == "bitplane":
        return from_float(w, qc.n_bits, qc.spec,
                          per_block_scale=qc.per_block_scale)
    if qc.mode == "fake":
        return fq_from_float(w, qc.n_bits, qc.spec)
    raise ValueError(qc.mode)


def _is_quant(x) -> bool:
    from ..serve.deploy import BitplaneServingWeight, ServingWeight
    return isinstance(x, (QuantizedTensor, FakeQuantTensor, ServingWeight,
                          BitplaneServingWeight))


def materialize(params: Any, dtype=None) -> Any:
    """Quantized leaves -> plain weight arrays (whole-tree dequant).

    Retained for offline tooling (checkpoint export, analysis); the model
    forward paths use :func:`prepare_params` + :func:`qmatmul` instead and
    never materialize a whole tree per step."""
    from ..serve.deploy import (BitplaneServingWeight, ServingWeight,
                                bitplane_serving_compose, serving_compose)

    def conv(x):
        if isinstance(x, QuantizedTensor):
            return compose(x, dtype)
        if isinstance(x, FakeQuantTensor):
            return fq_compose(x, dtype)
        if isinstance(x, ServingWeight):
            return serving_compose(x, dtype or jnp.bfloat16)
        if isinstance(x, BitplaneServingWeight):
            return bitplane_serving_compose(x, dtype or jnp.bfloat16)
        if dtype is not None and isinstance(x, jnp.ndarray) \
                and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(conv, params, is_leaf=_is_quant)


def qdense(w: Any, dtype=None) -> jnp.ndarray:
    """Dequantize ONE weight leaf to a plain array (the dense backend).

    The only sanctioned dequantization entry point outside ``kernels/``:
    call sites that genuinely need a dense weight (ragged MoE dispatch,
    the lax-conv CNN path) go through here so the packed format keeps a
    single owner."""
    from ..serve.deploy import (BitplaneServingWeight, ServingWeight,
                                bitplane_serving_compose, serving_compose)
    if isinstance(w, QuantizedTensor):
        return compose(w, dtype)
    if isinstance(w, FakeQuantTensor):
        return fq_compose(w, dtype)
    if isinstance(w, ServingWeight):
        return serving_compose(w, dtype or jnp.bfloat16)
    if isinstance(w, BitplaneServingWeight):
        return bitplane_serving_compose(w, dtype or jnp.bfloat16)
    if dtype is not None and isinstance(w, jnp.ndarray) \
            and jnp.issubdtype(w.dtype, jnp.floating):
        return w.astype(dtype)
    return w


def _qmatmul_packed(x: jnp.ndarray, sw, backend: str) -> jnp.ndarray:
    """x (..., K) @ packed ServingWeight (Kp, Np) -> (..., N)."""
    from ..kernels.packed_matmul import packed_matmul
    from ..kernels.ref import packed_matmul_ref
    from ..serve.deploy import serving_to_packed_layout
    pk = serving_to_packed_layout(sw)
    n = sw.shape[-1]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if backend == "pallas":
        y = packed_matmul(x2, pk.w_int, pk.scale, bits=pk.bits,
                          wbr=pk.wbr, wbc=pk.wbc)
    else:                                                  # 'ref'
        y = packed_matmul_ref(x2, pk.w_int, pk.scale, pk.bits,
                              pk.wbr, pk.wbc)
    return y[:, :n].reshape(*lead, n).astype(x.dtype)


def _qmatmul_bitplane(x: jnp.ndarray, sw, backend: str) -> jnp.ndarray:
    """x (..., K) @ bit-plane BitplaneServingWeight (Kp, Np) -> (..., N)."""
    from ..kernels.bitplane_matmul import bitplane_matmul
    from ..kernels.ref import bitplane_matmul_ref
    from ..serve.deploy import serving_to_bitplane_layout
    bl = serving_to_bitplane_layout(sw)
    n = sw.shape[-1]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if backend == "ref":
        y = bitplane_matmul_ref(x2, bl.planes_packed, bl.sign_packed,
                                bl.mask, bl.scale, bl.wbr, bl.wbc)
    else:                                      # 'bitplane' / 'pallas'
        y = bitplane_matmul(x2, bl.planes_packed, bl.sign_packed, bl.mask,
                            bl.scale, n_bits=bl.n_bits, wbr=bl.wbr,
                            wbc=bl.wbc)
    return y[:, :n].reshape(*lead, n).astype(x.dtype)


def qmatmul(x: jnp.ndarray, w: Any, *, backend: Optional[str] = None
            ) -> jnp.ndarray:
    """y = x @ W for any weight representation (the model-side matmul).

    ``x``: (..., K) activations; ``w``: plain array, QuantizedTensor,
    FakeQuantTensor, ServingWeight or BitplaneServingWeight with trailing
    (K-ish, N) dims.  Deployed leaves execute on their compressed form
    under a non-dense backend — ``pallas`` runs the leaf's Pallas kernel,
    ``ref`` its jnp oracle, ``bitplane`` the plane-sliced kernel (and
    only that: a packed ServingWeight under ``bitplane`` falls back to
    the dense dequant dot, keeping the backend's byte accounting honest).
    Every other combination dequantizes the single leaf in-graph and
    runs a plain dot."""
    from ..serve.deploy import BitplaneServingWeight, ServingWeight
    backend = backend or current_matmul_backend()
    if _ACT_RECORDERS and isinstance(w, BitplaneServingWeight) and w.tag:
        # Autotune calibration (serve.autotune.sensitivity): capture the
        # per-input-feature second moment of the activations feeding each
        # tagged bit-plane leaf.  Appends are in layer order because the
        # calibration forward runs the layer loop eagerly (scan_layers
        # off), so the recorder can restack per-layer slices.
        x2 = jnp.mean(jnp.square(
            x.reshape(-1, x.shape[-1]).astype(jnp.float32)), axis=0)
        _ACT_RECORDERS[-1].setdefault(w.tag, []).append(x2)
    if isinstance(w, BitplaneServingWeight) and backend != "dense" \
            and w.sign.ndim == 2:
        return _qmatmul_bitplane(x, w, backend)
    if isinstance(w, ServingWeight) and backend in ("pallas", "ref") \
            and w.w_int.ndim == 2:
        return _qmatmul_packed(x, w, backend)
    if isinstance(w, ServingWeight) and backend == "bitplane" \
            and "bitplane-packed-fallback" not in _WARNED_FALLBACKS:
        # once per process (trace-time): the engine warns with leaf paths
        # at construction and the graph lint reports every affected leaf
        _WARNED_FALLBACKS.add("bitplane-packed-fallback")
        import warnings
        warnings.warn(
            "qmatmul: packed ServingWeight under backend='bitplane' falls "
            "back to the in-graph dense dequant dot (the bitplane kernel "
            "streams only the plane-sliced layout; deploy with "
            "layout='bitplane')", stacklevel=2)
    return x @ qdense(w, x.dtype)


_WARNED_FALLBACKS: set = set()

# Stack of active calibration stores (dicts tag -> [x2 per consuming
# call, in call order]).  A list-as-stack so nested calibrations stay
# isolated; empty in normal serving, so the hot path pays one falsy
# check per qmatmul.
_ACT_RECORDERS: list = []


@contextlib.contextmanager
def record_qmatmul_inputs(store: Optional[dict] = None):
    """Capture activation second moments for tagged bit-plane leaves.

    Inside the context every ``qmatmul`` against a ``tag``-labelled
    BitplaneServingWeight appends the (K,)-shaped mean-square of its
    input activations to ``store[tag]``.  Meant for eager (un-scanned)
    calibration forwards — under a traced scan the captured values would
    be tracers.  Yields the store."""
    store = {} if store is None else store
    _ACT_RECORDERS.append(store)
    try:
        yield store
    finally:
        _ACT_RECORDERS.pop()


def prepare_params(params: Any, dtype=None) -> Any:
    """Once-per-step param prep (before the layer scan).

    Casts plain float leaves to the compute dtype and composes bit-plane
    ``QuantizedTensor`` leaves up-front (their bit axis leads, so they
    cannot be sliced by the layer scan).  FakeQuantTensor / ServingWeight
    / BitplaneServingWeight leaves stay in their (scan-sliceable) storage
    — :func:`qmatmul` consumes them one layer at a time, so the serving
    path never holds a whole dequantized param tree."""
    from ..serve.deploy import BitplaneServingWeight, ServingWeight

    def conv(x):
        if isinstance(x, QuantizedTensor):
            return compose(x, dtype)
        if isinstance(x, (FakeQuantTensor, ServingWeight,
                          BitplaneServingWeight)):
            return x
        if dtype is not None and isinstance(x, jnp.ndarray) \
                and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(conv, params, is_leaf=_is_quant)


def act_quant(x: jnp.ndarray, beta: Optional[jnp.ndarray],
              qc: QuantConfig) -> jnp.ndarray:
    """Symmetric PACT activation quantization in front of a quantized matmul."""
    if not qc.enabled or qc.act_bits >= 32 or beta is None:
        return x
    return pact_sym_quant(x, beta.astype(x.dtype), qc.act_bits)


def make_beta(qc: QuantConfig, dtype=jnp.float32):
    return jnp.asarray(qc.pact_init, dtype) if qc.enabled and qc.act_bits < 32 \
        else None


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return jax.random.normal(key, (vocab, d), dtype) * 0.02


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap) if cap and cap > 0 else x
