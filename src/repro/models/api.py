"""Uniform Model API over all families (decoder-only, enc-dec).

Gives the launcher / dry-run / tests one surface:
  init, loss, prefill, decode_step, input specs per shape-cell.
Input specs are ShapeDtypeStructs (no allocation) — the dry-run lowers
against them directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeCell
from . import encdec, transformer


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _write_rows(big, small, slot, batch_dim: int):
    """Write ``small`` into ``big`` at offset ``slot`` along ``batch_dim``
    (zero offsets elsewhere — time axes write from position 0)."""
    starts = [jnp.zeros((), jnp.int32)] * big.ndim
    starts[batch_dim] = slot
    return jax.lax.dynamic_update_slice(big, small.astype(big.dtype),
                                        tuple(starts))


def cache_is_paged(cache) -> bool:
    """True if any KV sub-dict of a decode cache carries a block table."""
    if isinstance(cache, dict):
        return "table" in cache or any(cache_is_paged(v)
                                       for v in cache.values())
    return False


def _row_cache_view(cache, slot, fresh=None):
    """Single-slot view of a decode cache: paged sub-dicts keep the whole
    page pool but narrow the block table to ``slot``'s row; contiguous /
    recurrent leaves (stack, B, ...) are row-sliced on the batch dim.

    ``fresh`` (traced bool) zeroes *recurrent* rows — when the view starts
    a brand-new request (first prompt chunk), the slot's previous
    occupant's rwkv/mamba state must read as the zero init a standalone
    prefill would use.  KV rows have no such hazard (stale positions stay
    masked by the fill level) and pass through untouched."""
    if isinstance(cache, dict):
        if "table" in cache:
            return dict(cache, table=jax.lax.dynamic_slice_in_dim(
                cache["table"], slot, 1, axis=1))
        if "k" in cache and "v" in cache:
            return {k: jax.lax.dynamic_slice_in_dim(v, slot, 1, axis=1)
                    for k, v in cache.items()}
        return {k: _row_cache_view(v, slot, fresh) for k, v in cache.items()}
    row = jax.lax.dynamic_slice_in_dim(cache, slot, 1, axis=1)
    if fresh is not None:
        row = jnp.where(fresh, jnp.zeros_like(row), row)
    return row


def _row_cache_unview(big, row, slot):
    """Merge an updated single-slot view back: paged pools were scattered
    into in place (all slots share them) and replace wholesale, with the
    full block table restored; row-sliced leaves write back at ``slot``."""
    if isinstance(big, dict):
        if "table" in big:
            return dict(row, table=big["table"])
        return {k: _row_cache_unview(big[k], row[k], slot) for k in big}
    return _write_rows(big, row, slot, batch_dim=1)


@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig

    # ---- parameters ---------------------------------------------------
    def init(self, key) -> Any:
        if self.cfg.is_encdec:
            return encdec.init_encdec(key, self.cfg)
        return transformer.init_lm(key, self.cfg)

    def abstract_params(self) -> Any:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ---- losses / steps ------------------------------------------------
    def loss(self, params, batch) -> tuple:
        if self.cfg.is_encdec:
            return encdec.encdec_loss(params, self.cfg, batch)
        return transformer.loss_fn(params, self.cfg, batch)

    def prefill(self, params, batch, extra_slots: int = 0) -> tuple:
        """Full-sequence forward that also fills the decode cache.

        ``extra_slots`` reserves cache headroom for subsequent decode steps
        (a decode write past the cache end would clamp and corrupt)."""
        cfg = self.cfg
        if cfg.is_encdec:
            b, s = batch["tokens"].shape
            cache = encdec.encdec_init_cache(cfg, b, s + extra_slots)
            logits, cache, enc_out = encdec.encdec_forward(
                params, cfg, batch["frames"], batch["tokens"], cache,
                jnp.asarray(0, jnp.int32))
            return logits[:, -1], {"cache": cache, "enc_out": enc_out}
        b, s = batch["tokens"].shape
        extra = (cfg.vision_tokens if cfg.family == "vlm" else 0) + extra_slots
        cache = transformer.init_cache(cfg, b, s + extra)
        logits, _, cache = transformer.forward(
            params, cfg, batch["tokens"],
            vision_embeds=batch.get("vision_embeds"),
            positions=batch.get("positions"), cache=cache,
            index=jnp.asarray(0, jnp.int32))
        return logits[:, -1], {"cache": cache}

    def decode_step(self, params, tokens, state, index) -> tuple:
        """One decode step.  ``index`` is either the scalar shared fill
        level (train / dry-run paths) or a per-slot (B,) vector of fill
        levels (request-level serving: each slot advances independently)."""
        cfg = self.cfg
        if cfg.is_encdec:
            logits, cache = encdec.encdec_decode_step(
                params, cfg, tokens, state["cache"], index, state["enc_out"])
            return logits, {**state, "cache": cache}
        logits, cache = transformer.decode_step(params, cfg, tokens,
                                                state["cache"], index)
        return logits, {**state, "cache": cache}

    def verify_step(self, params, tokens, state, index) -> tuple:
        """Batched multi-token decode forward (speculative verification).

        ``tokens`` (B, W) are each slot's last accepted token followed by
        its draft proposals, written at fill levels ``index .. index+W-1``
        — the same cache-write machinery chunked prefill uses, so paged
        and contiguous layouts both work.  Returns (B, W, V) logits."""
        if self.cfg.is_encdec:
            raise NotImplementedError(
                "speculative verify is decoder-only (KV rollback is "
                "positional; enc-dec cross attention is out of scope)")
        logits, cache = transformer.verify_step(params, self.cfg, tokens,
                                                state["cache"], index)
        return logits, {**state, "cache": cache}

    def init_decode_state(self, params, batch, n_slots: int, max_len: int,
                          page_size: int = 0,
                          n_pages: Optional[int] = None) -> Any:
        """Empty decode state for ``n_slots`` continuous-batching slots.

        The state *tree* (cache layout per family, enc-dec encoder buffer)
        comes from ``jax.eval_shape`` over this model's own prefill on the
        example ``batch`` — no forward pass runs.  ``page_size > 0`` builds
        the paged layout (global pool of ``n_pages`` pages, default
        ``1 + n_slots * nb`` so worst-case demand plus the trash page
        always fits; allocators may size it tighter) instead of contiguous
        ``max_len``-wide slots.  Prompts are inserted per-request via
        :meth:`prefill_at` / :meth:`prefill_chunk_at`."""
        sub = jax.eval_shape(
            lambda p, b: self.prefill(p, b, extra_slots=0)[1], params, batch)
        if page_size > 0:
            nb = -(-max_len // page_size)
            n_pages = n_pages or (1 + n_slots * nb)
            cache = transformer.paginate_cache_tree(
                sub["cache"], n_slots, n_pages, page_size, nb)
        else:
            cache = transformer.rebatch_cache_tree(sub["cache"], n_slots,
                                                   max_len)
        state = {"cache": cache}
        if "enc_out" in sub:
            eo = sub["enc_out"]
            state["enc_out"] = jnp.zeros((n_slots, *eo.shape[1:]), eo.dtype)
        return state

    def prefill_chunk_at(self, params, batch, state, slot, start) -> tuple:
        """Insert a prompt *chunk* into batch row ``slot`` of a live state.

        ``batch`` carries the chunk's tokens (1, W) — plus ``frames`` /
        ``vision_embeds`` on the first chunk, which must start at
        ``start == 0`` — and ``start`` is the cache position of the chunk's
        first token (VLM text chunks count from ``vision_tokens``).  The
        chunk runs through the family forward against a single-slot view of
        the state, attending over the slot's already-cached prefix, so
        chunk-by-chunk insertion reproduces a monolithic prefill
        bit-for-bit (stale positions past the written prefix stay masked by
        the fill level).  Returns the full (1, W, V) chunk logits — callers
        take the last *real* column when the final chunk is padded — and
        the updated state."""
        cfg = self.cfg
        slot = jnp.asarray(slot, jnp.int32)
        start = jnp.asarray(start, jnp.int32)
        row_cache = _row_cache_view(state["cache"], slot, fresh=(start == 0))
        new_state = dict(state)
        if cfg.is_encdec:
            if "frames" in batch:
                logits, row_cache, enc_out = encdec.encdec_forward(
                    params, cfg, batch["frames"], batch["tokens"],
                    row_cache, start)
                new_state["enc_out"] = _write_rows(
                    state["enc_out"], enc_out, slot, batch_dim=0)
            else:
                enc_row = jax.lax.dynamic_slice_in_dim(
                    state["enc_out"], slot, 1, axis=0)
                logits, row_cache = encdec.encdec_decode_tokens(
                    params, cfg, batch["tokens"], row_cache, start, enc_row)
        else:
            positions = None
            if batch.get("vision_embeds") is None:
                pos1 = transformer.decode_positions(
                    start, 1, batch["tokens"].shape[1])
                positions = jnp.stack([pos1] * 3, axis=-1) if cfg.mrope \
                    else pos1
            logits, _, row_cache = transformer.forward(
                params, cfg, batch["tokens"],
                vision_embeds=batch.get("vision_embeds"),
                positions=positions, cache=row_cache, index=start)
        new_state["cache"] = _row_cache_unview(state["cache"], row_cache,
                                               slot)
        return logits, new_state

    def prefill_at(self, params, batch, state, slot) -> tuple:
        """Prefill ``batch`` (nb prompt rows) INTO an existing decode state.

        With a *paged* state this is single-row whole-prompt insertion —
        one :meth:`prefill_chunk_at` call at ``start=0``, writing through
        the slot's block table.  Contiguous states run a standalone prefill
        for the sub-batch and write the resulting cache /
        recurrent-state / encoder rows into batch rows [slot, slot+nb) of
        ``state`` — the continuous-batching insertion primitive (a prompt
        joins a live decode batch without touching the other slots).  Every
        cache leaf is stacked (L, B, ...) so the batch dim is 1;
        ``enc_out`` carries batch at dim 0.  The target cache's time axis
        must be at least the sub-batch's prefill width; stale positions
        past the prompt stay masked by the per-slot fill level.  Returns
        (last-token logits of the inserted rows, updated state)."""
        if cache_is_paged(state["cache"]):
            logits, new_state = self.prefill_chunk_at(params, batch, state,
                                                      slot, 0)
            return logits[:, -1], new_state
        logits, sub = self.prefill(params, batch, extra_slots=0)
        slot = jnp.asarray(slot, jnp.int32)
        new_state = dict(state)
        new_state["cache"] = jax.tree_util.tree_map(
            lambda big, small: _write_rows(big, small, slot, batch_dim=1),
            state["cache"], sub["cache"])
        if "enc_out" in state:
            if sub["enc_out"].shape[1] != state["enc_out"].shape[1]:
                raise ValueError(
                    "enc-dec slot insertion needs the same encoder length "
                    f"as the live batch: {sub['enc_out'].shape[1]} != "
                    f"{state['enc_out'].shape[1]} (cross-attention has no "
                    "per-row length masking)")
            new_state["enc_out"] = _write_rows(
                state["enc_out"], sub["enc_out"], slot, batch_dim=0)
        return logits, new_state

    # ---- abstract input specs per shape cell ----------------------------
    def train_batch_spec(self, cell: ShapeCell) -> Dict[str, Any]:
        cfg = self.cfg
        b, s = cell.global_batch, cell.seq_len
        if cfg.is_encdec:
            return {
                "frames": _sds((b, s, cfg.d_model), jnp.bfloat16),
                "tokens": _sds((b, s), jnp.int32),
                "labels": _sds((b, s), jnp.int32),
            }
        if cfg.family == "vlm":
            tv = cfg.vision_tokens
            st = s - tv
            return {
                "tokens": _sds((b, st), jnp.int32),
                "labels": _sds((b, st), jnp.int32),
                "vision_embeds": _sds((b, tv, cfg.d_model), jnp.bfloat16),
                "positions": _sds((b, s, 3), jnp.int32),
            }
        return {"tokens": _sds((b, s), jnp.int32),
                "labels": _sds((b, s), jnp.int32)}

    def decode_state_spec(self, cell: ShapeCell) -> Dict[str, Any]:
        cfg = self.cfg
        b, s = cell.global_batch, cell.seq_len
        if cfg.is_encdec:
            cache = jax.eval_shape(
                lambda: encdec.encdec_init_cache(cfg, b, s))
            return {"cache": cache,
                    "enc_out": _sds((b, s, cfg.d_model),
                                    jnp.dtype(cfg.dtype))}
        cache = jax.eval_shape(lambda: transformer.init_cache(cfg, b, s))
        return {"cache": cache}

    def decode_token_spec(self, cell: ShapeCell):
        return _sds((cell.global_batch, 1), jnp.int32)


def build(cfg: ModelConfig) -> ModelAPI:
    return ModelAPI(cfg)
