"""Uniform Model API over all families (decoder-only, enc-dec).

Gives the launcher / dry-run / tests one surface:
  init, loss, prefill, decode_step, input specs per shape-cell.
Input specs are ShapeDtypeStructs (no allocation) — the dry-run lowers
against them directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeCell
from . import encdec, transformer


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _write_rows(big, small, slot, batch_dim: int):
    """Write ``small`` into ``big`` at offset ``slot`` along ``batch_dim``
    (zero offsets elsewhere — time axes write from position 0)."""
    starts = [jnp.zeros((), jnp.int32)] * big.ndim
    starts[batch_dim] = slot
    return jax.lax.dynamic_update_slice(big, small.astype(big.dtype),
                                        tuple(starts))


@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig

    # ---- parameters ---------------------------------------------------
    def init(self, key) -> Any:
        if self.cfg.is_encdec:
            return encdec.init_encdec(key, self.cfg)
        return transformer.init_lm(key, self.cfg)

    def abstract_params(self) -> Any:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ---- losses / steps ------------------------------------------------
    def loss(self, params, batch) -> tuple:
        if self.cfg.is_encdec:
            return encdec.encdec_loss(params, self.cfg, batch)
        return transformer.loss_fn(params, self.cfg, batch)

    def prefill(self, params, batch, extra_slots: int = 0) -> tuple:
        """Full-sequence forward that also fills the decode cache.

        ``extra_slots`` reserves cache headroom for subsequent decode steps
        (a decode write past the cache end would clamp and corrupt)."""
        cfg = self.cfg
        if cfg.is_encdec:
            b, s = batch["tokens"].shape
            cache = encdec.encdec_init_cache(cfg, b, s + extra_slots)
            logits, cache, enc_out = encdec.encdec_forward(
                params, cfg, batch["frames"], batch["tokens"], cache,
                jnp.asarray(0, jnp.int32))
            return logits[:, -1], {"cache": cache, "enc_out": enc_out}
        b, s = batch["tokens"].shape
        extra = (cfg.vision_tokens if cfg.family == "vlm" else 0) + extra_slots
        cache = transformer.init_cache(cfg, b, s + extra)
        logits, _, cache = transformer.forward(
            params, cfg, batch["tokens"],
            vision_embeds=batch.get("vision_embeds"),
            positions=batch.get("positions"), cache=cache,
            index=jnp.asarray(0, jnp.int32))
        return logits[:, -1], {"cache": cache}

    def decode_step(self, params, tokens, state, index) -> tuple:
        """One decode step.  ``index`` is either the scalar shared fill
        level (train / dry-run paths) or a per-slot (B,) vector of fill
        levels (request-level serving: each slot advances independently)."""
        cfg = self.cfg
        if cfg.is_encdec:
            logits, cache = encdec.encdec_decode_step(
                params, cfg, tokens, state["cache"], index, state["enc_out"])
            return logits, {**state, "cache": cache}
        logits, cache = transformer.decode_step(params, cfg, tokens,
                                                state["cache"], index)
        return logits, {**state, "cache": cache}

    def prefill_at(self, params, batch, state, slot) -> tuple:
        """Prefill ``batch`` (nb prompt rows) INTO an existing decode state.

        Runs a standalone prefill for the sub-batch and writes the resulting
        cache / recurrent-state / encoder rows into batch rows
        [slot, slot+nb) of ``state`` — the continuous-batching insertion
        primitive (a prompt joins a live decode batch without touching the
        other slots).  Every cache leaf is stacked (L, B, ...) so the batch
        dim is 1; ``enc_out`` carries batch at dim 0.  The target cache's
        time axis must be at least the sub-batch's prefill width; stale
        positions past the prompt stay masked by the per-slot fill level.
        Returns (last-token logits of the inserted rows, updated state)."""
        logits, sub = self.prefill(params, batch, extra_slots=0)
        slot = jnp.asarray(slot, jnp.int32)
        new_state = dict(state)
        new_state["cache"] = jax.tree_util.tree_map(
            lambda big, small: _write_rows(big, small, slot, batch_dim=1),
            state["cache"], sub["cache"])
        if "enc_out" in state:
            if sub["enc_out"].shape[1] != state["enc_out"].shape[1]:
                raise ValueError(
                    "enc-dec slot insertion needs the same encoder length "
                    f"as the live batch: {sub['enc_out'].shape[1]} != "
                    f"{state['enc_out'].shape[1]} (cross-attention has no "
                    "per-row length masking)")
            new_state["enc_out"] = _write_rows(
                state["enc_out"], sub["enc_out"], slot, batch_dim=0)
        return logits, new_state

    # ---- abstract input specs per shape cell ----------------------------
    def train_batch_spec(self, cell: ShapeCell) -> Dict[str, Any]:
        cfg = self.cfg
        b, s = cell.global_batch, cell.seq_len
        if cfg.is_encdec:
            return {
                "frames": _sds((b, s, cfg.d_model), jnp.bfloat16),
                "tokens": _sds((b, s), jnp.int32),
                "labels": _sds((b, s), jnp.int32),
            }
        if cfg.family == "vlm":
            tv = cfg.vision_tokens
            st = s - tv
            return {
                "tokens": _sds((b, st), jnp.int32),
                "labels": _sds((b, st), jnp.int32),
                "vision_embeds": _sds((b, tv, cfg.d_model), jnp.bfloat16),
                "positions": _sds((b, s, 3), jnp.int32),
            }
        return {"tokens": _sds((b, s), jnp.int32),
                "labels": _sds((b, s), jnp.int32)}

    def decode_state_spec(self, cell: ShapeCell) -> Dict[str, Any]:
        cfg = self.cfg
        b, s = cell.global_batch, cell.seq_len
        if cfg.is_encdec:
            cache = jax.eval_shape(
                lambda: encdec.encdec_init_cache(cfg, b, s))
            return {"cache": cache,
                    "enc_out": _sds((b, s, cfg.d_model),
                                    jnp.dtype(cfg.dtype))}
        cache = jax.eval_shape(lambda: transformer.init_cache(cfg, b, s))
        return {"cache": cache}

    def decode_token_spec(self, cell: ShapeCell):
        return _sds((cell.global_batch, 1), jnp.int32)


def build(cfg: ModelConfig) -> ModelAPI:
    return ModelAPI(cfg)
