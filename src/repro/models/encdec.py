"""Encoder-decoder model (seamless-m4t-style): conformer-ish speech encoder
(stub frontend supplies precomputed frame embeddings) + causal text decoder
with cross-attention.  Same stacked-scan layout as the decoder-only LM."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..dist.sharding import constraint, shard_params_tree
from .attention import attn_forward
from .common import (embed_init, make_weight, prepare_params, qmatmul,
                     rms_norm)
from .transformer import scan_or_loop
from .ffn import mlp_forward


def _enc_block_init(key, cfg: ModelConfig, stack: int) -> Dict:
    d, dh = cfg.d_model, cfg.head_dim
    qc = cfg.quant
    ks = jax.random.split(key, 8)
    p = {
        "ln_attn": jnp.zeros((stack, d), jnp.float32),
        "ln_mlp": jnp.zeros((stack, d), jnp.float32),
        "attn": {
            "wq": make_weight(ks[0], (stack, d, cfg.n_heads * dh), qc),
            "wk": make_weight(ks[1], (stack, d, cfg.n_kv_heads * dh), qc),
            "wv": make_weight(ks[2], (stack, d, cfg.n_kv_heads * dh), qc),
            "wo": make_weight(ks[3], (stack, cfg.n_heads * dh, d), qc),
        },
        "mlp": {
            "w_in": make_weight(ks[4], (stack, d, cfg.d_ff), qc),
            "w_out": make_weight(ks[5], (stack, cfg.d_ff, d), qc),
        },
    }
    if cfg.conformer_encoder:
        p["ln_conv"] = jnp.zeros((stack, d), jnp.float32)
        p["conv_pw1"] = make_weight(ks[6], (stack, d, 2 * d), qc)
        p["conv_dw"] = jax.random.normal(
            jax.random.fold_in(ks[6], 1), (stack, 15, d), jnp.float32) * 0.1
        p["conv_pw2"] = make_weight(ks[7], (stack, d, d), qc)
    return p


def _dec_block_init(key, cfg: ModelConfig, stack: int) -> Dict:
    d, dh = cfg.d_model, cfg.head_dim
    qc = cfg.quant
    ks = jax.random.split(key, 10)
    return {
        "ln_self": jnp.zeros((stack, d), jnp.float32),
        "ln_cross": jnp.zeros((stack, d), jnp.float32),
        "ln_mlp": jnp.zeros((stack, d), jnp.float32),
        "self_attn": {
            "wq": make_weight(ks[0], (stack, d, cfg.n_heads * dh), qc),
            "wk": make_weight(ks[1], (stack, d, cfg.n_kv_heads * dh), qc),
            "wv": make_weight(ks[2], (stack, d, cfg.n_kv_heads * dh), qc),
            "wo": make_weight(ks[3], (stack, cfg.n_heads * dh, d), qc),
        },
        "cross_attn": {
            "wq": make_weight(ks[4], (stack, d, cfg.n_heads * dh), qc),
            "wk": make_weight(ks[5], (stack, d, cfg.n_kv_heads * dh), qc),
            "wv": make_weight(ks[6], (stack, d, cfg.n_kv_heads * dh), qc),
            "wo": make_weight(ks[7], (stack, cfg.n_heads * dh, d), qc),
        },
        "mlp": {
            "w_in": make_weight(ks[8], (stack, d, cfg.d_ff), qc),
            "w_out": make_weight(ks[9], (stack, cfg.d_ff, d), qc),
        },
    }


def init_encdec(key, cfg: ModelConfig) -> Dict:
    ks = jax.random.split(key, 4)
    return {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model),
        "enc_layers": _enc_block_init(ks[1], cfg, cfg.enc_layers),
        "dec_layers": _dec_block_init(ks[2], cfg, cfg.n_layers),
        "enc_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }


def _conformer_conv(lp, x):
    """Pointwise-GLU -> depthwise conv -> pointwise (simplified Conformer)."""
    h = qmatmul(x, lp["conv_pw1"])
    a, b = jnp.split(h, 2, axis=-1)
    h = a * jax.nn.sigmoid(b)                     # GLU
    w = lp["conv_dw"]                             # (K, d)
    k, d = w.shape
    h = jax.lax.conv_general_dilated(
        h, w[:, None, :].astype(h.dtype), (1,), [(k // 2, k - 1 - k // 2)],
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=d)
    return qmatmul(jax.nn.silu(h), lp["conv_pw2"])


def encode(mp, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, S_enc, d_model) precomputed frontend embeddings."""
    h = frames.astype(jnp.dtype(cfg.dtype))
    b, s, _ = h.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(carry, lp):
        h = carry
        x = rms_norm(h, lp["ln_attn"])
        out, _ = attn_forward(lp["attn"], x, pos, n_heads=cfg.n_heads,
                              n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
                              rope_theta=cfg.rope_theta, causal=False)
        h = h + out
        if cfg.conformer_encoder:
            h = h + _conformer_conv(lp, rms_norm(h, lp["ln_conv"]))
        h = h + mlp_forward(lp["mlp"], rms_norm(h, lp["ln_mlp"]), "gelu")
        return constraint(h, "batch", None, None), None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = scan_or_loop(body, h, mp["enc_layers"], cfg.scan_layers,
                        cfg.enc_layers)
    return rms_norm(h, mp["enc_norm"])


def decode(mp, cfg: ModelConfig, tokens, enc_out, cache=None, index=None):
    from .transformer import decode_positions

    h = jnp.take(mp["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    b, s, _ = h.shape
    if index is None:
        pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    else:
        pos = decode_positions(index, b, s)
    enc_pos = jnp.broadcast_to(
        jnp.arange(enc_out.shape[1])[None, :], (b, enc_out.shape[1]))

    from .transformer import _index_cache, _update_cache

    def body(carry, lp):
        h, cache_c, li = carry
        layer_cache = _index_cache(cache_c, li) if cache_c is not None \
            else None
        out, new_lc = attn_forward(
            lp["self_attn"], rms_norm(h, lp["ln_self"]), pos,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
            rope_theta=cfg.rope_theta, causal=True,
            cache=layer_cache, cache_index=index)
        if cache_c is not None:
            cache_c = _update_cache(cache_c, new_lc, li)
        h = h + out
        out, _ = attn_forward(
            lp["cross_attn"], rms_norm(h, lp["ln_cross"]), pos,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
            rope_theta=cfg.rope_theta, x_kv=enc_out, kv_positions=enc_pos)
        h = h + out
        h = h + mlp_forward(lp["mlp"], rms_norm(h, lp["ln_mlp"]), "gelu")
        return (constraint(h, "batch", None, None), cache_c, li + 1), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (h, new_cache, _), _ = scan_or_loop(
        body, (h, cache, jnp.asarray(0, jnp.int32)), mp["dec_layers"],
        cfg.scan_layers, cfg.n_layers)
    h = rms_norm(h, mp["final_norm"])
    logits = qmatmul(h, mp["embed"].T).astype(jnp.float32)
    return constraint(logits, "batch", None, "vocab"), new_cache


def encdec_forward(params, cfg: ModelConfig, frames, tokens,
                   cache=None, index=None):
    mp = shard_params_tree(prepare_params(params, jnp.dtype(cfg.dtype)))
    enc_out = encode(mp, cfg, frames)
    logits, new_cache = decode(mp, cfg, tokens, enc_out, cache, index)
    return logits, new_cache, enc_out


def encdec_loss(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    logits, _, _ = encdec_forward(params, cfg, batch["frames"],
                                  batch["tokens"])
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - ll)
    return ce, dict(ce=ce, aux=jnp.asarray(0.0))


def encdec_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    from .transformer import make_kv_cache
    return make_kv_cache(cfg, cfg.n_layers, batch, max_len)


def encdec_decode_tokens(params, cfg: ModelConfig, tokens, cache, index,
                         enc_out):
    """Decoder-only forward over a (B, S) token block starting at cache
    position ``index`` (encoder output precomputed) — full (B, S, V)
    logits.  S=1 is the decode step; S>1 is a chunked-prefill insertion."""
    mp = shard_params_tree(prepare_params(params, jnp.dtype(cfg.dtype)))
    return decode(mp, cfg, tokens, enc_out, cache, index)


def encdec_decode_step(params, cfg: ModelConfig, tokens, cache, index,
                       enc_out):
    """One decoder token; encoder output precomputed at prefill time.
    ``index`` may be a scalar or a per-slot (B,) vector."""
    logits, new_cache = encdec_decode_tokens(params, cfg, tokens, cache,
                                             index, enc_out)
    return logits[:, -1], new_cache
