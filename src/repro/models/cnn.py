"""Paper-faithful CNN models (ResNet-CIFAR / VGG-BN) with BWQ-A conv layers.

Conv weights are stored in their CSP-flattened 2-D form (C_in*kh*kw, C_out)
— exactly the layout the paper blocks into WBs (Fig. 2b) — as
QuantizedTensor (bit-plane) leaves, and reshaped back to 4-D at
materialization time.  These models drive the Table-II / Fig-9..13
benchmarks and the CIFAR example.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from ..core.bitrep import from_float
from ..core.fakequant import fq_from_float
from ..core.pact import pact_quant
from .common import QuantConfig, qdense, qmatmul


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class ConvMeta:
    """Static conv geometry (kept out of grad's differentiable leaves)."""
    c_in: int
    c_out: int
    k: int


def conv_init(key, c_in: int, c_out: int, k: int, qc: QuantConfig):
    fan_in = c_in * k * k
    w2d = jax.random.normal(key, (fan_in, c_out)) * jnp.sqrt(2.0 / fan_in)
    meta = ConvMeta(c_in=c_in, c_out=c_out, k=k)
    if qc.mode == "bitplane":
        return {"qt": from_float(w2d, qc.n_bits, qc.spec,
                                 per_block_scale=qc.per_block_scale),
                "meta": meta}
    if qc.mode == "fake":
        return {"qt": fq_from_float(w2d, qc.n_bits, qc.spec), "meta": meta}
    return {"qt": w2d, "meta": meta}


def conv_apply(p: Dict, x: jnp.ndarray, stride: int = 1,
               act_beta=None, qc: QuantConfig | None = None) -> jnp.ndarray:
    """x: (B, H, W, C_in) NHWC.

    Packed serving weights take the im2col path: input patches are
    extracted in the (C_in, kh, kw) order of the CSP-flattened 2-D weight
    — exactly the layout the paper blocks into WBs — and pushed through
    ``qmatmul``, so a deployed conv executes on the compressed
    representation.  QAT / plain weights keep the fused lax conv."""
    from ..serve.deploy import BitplaneServingWeight, ServingWeight
    meta = p["meta"]
    wq = p["qt"]
    if act_beta is not None and qc is not None and qc.act_bits < 32:
        x = pact_quant(x, act_beta, qc.act_bits)     # paper PACT (post-ReLU)
    if isinstance(wq, (ServingWeight, BitplaneServingWeight)):
        patches = jax.lax.conv_general_dilated_patches(
            x, (meta.k, meta.k), (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return qmatmul(patches, wq)
    w2d = qdense(wq)
    w = w2d.reshape(meta.c_in, meta.k, meta.k, meta.c_out)
    w = jnp.transpose(w, (1, 2, 0, 3))               # HWIO
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _bn_apply(p, x, eps=1e-5):
    # batch-norm in inference style folded to per-channel affine over batch
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    xn = (x - mu) * jax.lax.rsqrt(var + eps)
    return xn * p["scale"] + p["bias"]


# ---------------------------------------------------------------------------
# ResNet (CIFAR): depth = 6n+2 (20, 32, ...) or basic-18/34 style
# ---------------------------------------------------------------------------

def resnet_init(key, qc: QuantConfig, depth: int = 20,
                num_classes: int = 10) -> Dict:
    n = (depth - 2) // 6
    widths = [16, 32, 64]
    ks = iter(jax.random.split(key, 3 * n * 2 + 4))
    params: Dict[str, Any] = {
        "stem": conv_init(next(ks), 3, 16, 3, qc), "stem_bn": _bn_init(16),
        "blocks": [], "betas": []}
    c_in = 16
    for stage, c in enumerate(widths):
        for b in range(n):
            stride = 2 if (stage > 0 and b == 0) else 1
            blk = {
                "conv1": conv_init(next(ks), c_in, c, 3, qc),
                "bn1": _bn_init(c),
                "conv2": conv_init(next(ks), c, c, 3, qc),
                "bn2": _bn_init(c),
            }
            if stride != 1 or c_in != c:
                blk["proj"] = conv_init(jax.random.fold_in(next(ks), 7),
                                        c_in, c, 1, qc)
            params["blocks"].append(blk)
            c_in = c
    params["head_w"] = jax.random.normal(next(ks), (64, num_classes)) * 0.01
    params["head_b"] = jnp.zeros((num_classes,))
    if qc.enabled and qc.act_bits < 32:
        params["beta"] = jnp.asarray(qc.pact_init)
    return params


def resnet_apply(params: Dict, x: jnp.ndarray, qc: QuantConfig):
    beta = params.get("beta")
    h = conv_apply(params["stem"], x)
    h = jax.nn.relu(_bn_apply(params["stem_bn"], h))
    for blk in params["blocks"]:
        # stage-entry blocks (the ones with a projection) downsample 2x
        stride = 2 if "proj" in blk else 1
        y = conv_apply(blk["conv1"], h, stride, beta, qc)
        y = jax.nn.relu(_bn_apply(blk["bn1"], y))
        y = conv_apply(blk["conv2"], y, 1, beta, qc)
        y = _bn_apply(blk["bn2"], y)
        sc = conv_apply(blk["proj"], h, stride) if "proj" in blk else h
        h = jax.nn.relu(y + sc)
    h = jnp.mean(h, axis=(1, 2))
    return h @ params["head_w"] + params["head_b"]


# ---------------------------------------------------------------------------
# VGG-BN (CIFAR)
# ---------------------------------------------------------------------------

_VGG_PLANS = {
    11: [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"],
    16: [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
         512, 512, 512, "M", 512, 512, 512, "M"],
    19: [64, 64, "M", 128, 128, "M", 256, 256, 256, 256, "M",
         512, 512, 512, 512, "M", 512, 512, 512, 512, "M"],
}


def vgg_init(key, qc: QuantConfig, depth: int = 16,
             num_classes: int = 10) -> Dict:
    plan = _VGG_PLANS[depth]
    ks = iter(jax.random.split(key, len(plan) + 2))
    layers: List[Any] = []
    c_in = 3
    for item in plan:
        if item == "M":
            layers.append("M")
        else:
            layers.append({"conv": conv_init(next(ks), c_in, item, 3, qc),
                           "bn": _bn_init(item)})
            c_in = item
    params = {"layers": layers,
              "head_w": jax.random.normal(next(ks), (512, num_classes)) * 0.01,
              "head_b": jnp.zeros((num_classes,))}
    if qc.enabled and qc.act_bits < 32:
        params["beta"] = jnp.asarray(qc.pact_init)
    return params


def vgg_apply(params: Dict, x: jnp.ndarray, qc: QuantConfig):
    beta = params.get("beta")
    h = x
    first = True
    for layer in params["layers"]:
        if layer == "M":
            h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        else:
            h = conv_apply(layer["conv"], h, 1,
                           None if first else beta, qc)
            h = jax.nn.relu(_bn_apply(layer["bn"], h))
            first = False
    h = jnp.mean(h, axis=(1, 2))
    return h @ params["head_w"] + params["head_b"]


def cnn_loss(apply_fn, params, batch, qc: QuantConfig):
    logits = apply_fn(params, batch["images"], qc)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return jnp.mean(lse - ll), dict(acc=acc)
