"""Decoder-only LM assembly: dense / MoE / SSM / hybrid families.

One scan-over-layers drives training, prefill and decode; the layer body
dispatches on the config family.  Params hold stacked (L, ...) leaves.
``prepare_params`` runs once per step (outside the scan) to cast plain
floats and compose bit-plane tensors; scan-sliceable quantized storage
(FakeQuantTensor, packed ServingWeight) rides the scan untouched and is
consumed per layer by ``qmatmul`` — on the packed serving path the layer
code never sees a dequantized full-precision weight.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..dist.sharding import constraint, shard_params_tree
from .attention import attn_forward, init_attn
from .common import (act_quant, embed_init, make_weight,
                     prepare_params, qmatmul, rms_norm, softcap)
from .ffn import init_mlp, mlp_forward
from .moe import moe_forward
from .rwkv import init_rwkv6, rwkv6_forward, rwkv6_init_state
from .ssm import init_mamba2, mamba2_forward, mamba2_init_state


def _cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def scan_or_loop(body, carry, xs, use_scan: bool, length: int):
    """lax.scan or an unrolled python loop (cfg.scan_layers=False).

    The unrolled form exists for the dry-run's cost *calibration* lowering:
    XLA cost_analysis counts a scan body once, so exact FLOP/byte totals
    are obtained from small unrolled configs and scaled (launch/dryrun.py).
    """
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(length):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and any(l is not None for l in jax.tree_util.tree_leaves(ys[0])) \
            or (ys and ys[0] is not None):
        ys_stacked = jax.tree_util.tree_map(
            lambda *zs: jnp.stack(zs), *ys)
    else:
        ys_stacked = None
    return carry, ys_stacked


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, stack: int) -> Dict:
    """One stacked parameter set for ``stack`` homogeneous layers."""
    qc = cfg.quant
    dt = jnp.float32
    ks = jax.random.split(key, 8)
    d, dh = cfg.d_model, cfg.head_dim
    p: Dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "vlm"):
        p["ln_attn"] = jnp.zeros((stack, d), dt)
        p["ln_mlp"] = jnp.zeros((stack, d), dt)
        if cfg.use_post_norms:
            p["ln_attn_post"] = jnp.zeros((stack, d), dt)
            p["ln_mlp_post"] = jnp.zeros((stack, d), dt)
        p["attn"] = {
            "wq": make_weight(ks[0], (stack, d, cfg.n_heads * dh), qc, dtype=dt),
            "wk": make_weight(ks[1], (stack, d, cfg.n_kv_heads * dh), qc, dtype=dt),
            "wv": make_weight(ks[2], (stack, d, cfg.n_kv_heads * dh), qc, dtype=dt),
            "wo": make_weight(ks[3], (stack, cfg.n_heads * dh, d), qc, dtype=dt),
        }
        if cfg.qkv_bias:
            p["attn"]["bq"] = jnp.zeros((stack, cfg.n_heads * dh), dt)
            p["attn"]["bk"] = jnp.zeros((stack, cfg.n_kv_heads * dh), dt)
            p["attn"]["bv"] = jnp.zeros((stack, cfg.n_kv_heads * dh), dt)
        if cfg.family == "moe" or cfg.n_experts:
            p["moe"] = {
                "router_w": jax.random.normal(
                    ks[4], (stack, d, cfg.n_experts), jnp.float32) * 0.02,
                "expert_gate": make_weight(
                    ks[5], (stack, cfg.n_experts, d, cfg.d_ff), qc, dtype=dt),
                "expert_up": make_weight(
                    jax.random.fold_in(ks[5], 1),
                    (stack, cfg.n_experts, d, cfg.d_ff), qc, dtype=dt),
                "expert_down": make_weight(
                    jax.random.fold_in(ks[5], 2),
                    (stack, cfg.n_experts, cfg.d_ff, d), qc, dtype=dt),
            }
            if cfg.n_shared_experts:
                f = cfg.n_shared_experts * cfg.d_ff
                p["moe"]["shared_gate"] = make_weight(
                    ks[6], (stack, d, f), qc, dtype=dt)
                p["moe"]["shared_up"] = make_weight(
                    jax.random.fold_in(ks[6], 1), (stack, d, f), qc, dtype=dt)
                p["moe"]["shared_down"] = make_weight(
                    jax.random.fold_in(ks[6], 2), (stack, f, d), qc, dtype=dt)
        else:
            if cfg.mlp_kind == "swiglu":
                p["mlp"] = {
                    "w_gate": make_weight(ks[4], (stack, d, cfg.d_ff), qc, dtype=dt),
                    "w_up": make_weight(ks[5], (stack, d, cfg.d_ff), qc, dtype=dt),
                    "w_down": make_weight(ks[6], (stack, cfg.d_ff, d), qc, dtype=dt),
                }
            else:
                p["mlp"] = {
                    "w_in": make_weight(ks[4], (stack, d, cfg.d_ff), qc, dtype=dt),
                    "w_out": make_weight(ks[5], (stack, cfg.d_ff, d), qc, dtype=dt),
                }
        if qc.enabled and qc.act_bits < 32:
            p["beta_attn"] = jnp.full((stack,), qc.pact_init, dt)
            p["beta_mlp"] = jnp.full((stack,), qc.pact_init, dt)
    elif cfg.family == "ssm":        # rwkv6 (token-mix + channel-mix per layer)
        p = init_rwkv6(ks[0], d, cfg.n_heads, qc, stack=stack, d_ff=cfg.d_ff)
    elif cfg.family == "hybrid":     # zamba2 mamba trunk
        p = init_mamba2(ks[0], d, cfg.ssm_state, qc, expand=cfg.ssm_expand,
                        headdim=cfg.ssm_headdim, stack=stack)
        p["ln"] = jnp.zeros((stack, d), dt)
    else:
        raise ValueError(cfg.family)
    return p


def init_lm(key, cfg: ModelConfig) -> Dict:
    ks = jax.random.split(key, 6)
    d, dt = cfg.d_model, jnp.float32
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab, d, dt),
        "final_norm": jnp.zeros((d,), dt),
        "layers": _init_block(ks[1], cfg, cfg.n_layers),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = make_weight(
            ks[2], (d, cfg.vocab), cfg.quant, dtype=dt,
            quantize=cfg.quant.quantize_embeddings)
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        # zamba2: ONE shared attention block, invoked every k layers on
        # concat(hidden, original_embedding) (2*d input).
        params["shared_attn"] = init_attn(
            ks[3], d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.quant,
            make_weight, d_model_in=2 * d, dtype=dt)
        params["shared_ln"] = jnp.zeros((2 * d,), dt)
        params["shared_mlp"] = init_mlp(ks[5], d, cfg.d_ff, cfg.quant,
                                        kind=cfg.mlp_kind, dtype=dt)
        params["shared_ln2"] = jnp.zeros((d,), dt)
    if cfg.family == "vlm":
        params["vision_proj"] = make_weight(ks[4], (d, d), cfg.quant, dtype=dt)
    return params


def _index_cache(cache, i):
    """Slice layer i's cache out of stacked (L, ...) leaves."""
    return jax.tree_util.tree_map(
        lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False),
        cache)


def _update_cache(cache, new_layer, i):
    return jax.tree_util.tree_map(
        lambda c, nl: jax.lax.dynamic_update_index_in_dim(c, nl, i, 0),
        cache, new_layer)


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

def _attn_block(lp, h, positions, cfg: ModelConfig, is_local,
                cache=None, index=None):
    qc = cfg.quant
    x = rms_norm(h, lp["ln_attn"])
    x = act_quant(x, lp.get("beta_attn"), qc)
    window = jnp.where(is_local, cfg.sliding_window, 0) if \
        cfg.alt_local_global else (cfg.sliding_window or 0)
    # window as traced value: attention uses dynamic comparison, so pass
    # the array directly (0 disables).
    out, new_cache = attn_forward(
        lp["attn"], x, positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
        d_head=cfg.head_dim, rope_theta=cfg.rope_theta, causal=True,
        window=window, attn_softcap=cfg.attn_softcap, mrope=cfg.mrope,
        cache=cache, cache_index=index)
    if cfg.use_post_norms:
        out = rms_norm(out, lp["ln_attn_post"])
    return h + out, new_cache


def _mlp_block(lp, h, cfg: ModelConfig):
    qc = cfg.quant
    x = rms_norm(h, lp["ln_mlp"])
    x = act_quant(x, lp.get("beta_mlp"), qc)
    aux = jnp.asarray(0.0, jnp.float32)
    if "moe" in lp:
        out, aux = moe_forward(lp["moe"], x, cfg.top_k)
    else:
        out = mlp_forward(lp["mlp"], x, cfg.mlp_kind)
    if cfg.use_post_norms:
        out = rms_norm(out, lp["ln_mlp_post"])
    return h + out, aux


# ---------------------------------------------------------------------------
# full model walk
# ---------------------------------------------------------------------------

def _walk_dense(mp, cfg, h, positions, cache, index):
    """Scan over homogeneous attention+FFN layers."""
    n = cfg.n_layers
    is_local = (jnp.arange(n) % 2 == 0) if cfg.alt_local_global else \
        jnp.zeros((n,), bool)

    def body(carry, xs):
        # cache rides in the carry and is updated in place per layer —
        # scan carries alias buffers, so the KV cache is never duplicated
        # (xs/ys threading would double-buffer multi-GiB caches).
        h, aux, cache_c, li = carry
        lp, loc = xs
        layer_cache = _index_cache(cache_c, li) if cache_c is not None \
            else None
        h, new_lc = _attn_block(lp, h, positions, cfg, loc,
                                cache=layer_cache, index=index)
        if cache_c is not None:
            cache_c = _update_cache(cache_c, new_lc, li)
        h, aux_l = _mlp_block(lp, h, cfg)
        h = constraint(h, "batch", None, None)
        return (h, aux + aux_l, cache_c, li + 1), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (h, aux, new_cache, _), _ = scan_or_loop(
        body, (h, jnp.asarray(0.0, jnp.float32), cache,
               jnp.asarray(0, jnp.int32)),
        (mp["layers"], is_local), cfg.scan_layers, n)
    return h, aux, new_cache


def _walk_ssm(mp, cfg, h, cache, index):
    def body(carry, lp):
        h, aux, cache_c, li = carry
        layer_state = _index_cache(cache_c, li) if cache_c is not None \
            else None
        h, new_state = rwkv6_forward(lp, h, n_heads=cfg.n_heads,
                                     chunk=cfg.rwkv_chunk, state=layer_state)
        if cache_c is not None:
            cache_c = _update_cache(cache_c, new_state, li)
        h = constraint(h, "batch", None, None)
        return (h, aux, cache_c, li + 1), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (h, aux, new_cache, _), _ = scan_or_loop(
        body, (h, jnp.asarray(0.0, jnp.float32), cache,
               jnp.asarray(0, jnp.int32)), mp["layers"],
        cfg.scan_layers, cfg.n_layers)
    return h, aux, new_cache


def _walk_hybrid(mp, cfg, h, emb0, positions, cache, index):
    """zamba2: mamba trunk + ONE shared attention block every k layers.

    All decode states ride in the scan carries (in-place updates)."""
    period = cfg.hybrid_attn_every
    n = cfg.n_layers
    n_super = n // period if period else 0
    n_main = n_super * period
    shared = mp.get("shared_attn")

    def mamba_body(carry, lp):
        h, aux, mstates, li = carry
        layer_state = _index_cache(mstates, li) if mstates is not None \
            else None
        x = rms_norm(h, lp["ln"])
        out, new_state = mamba2_forward(
            {k: v for k, v in lp.items() if k != "ln"}, x,
            n_state=cfg.ssm_state, headdim=cfg.ssm_headdim,
            chunk=cfg.ssm_chunk, state=layer_state)
        if mstates is not None:
            mstates = _update_cache(mstates, new_state, li)
        h = constraint(h + out, "batch", None, None)
        return (h, aux, mstates, li + 1), None

    if cfg.remat:
        mamba_body = jax.checkpoint(mamba_body)

    cache_mamba = cache["mamba"] if cache is not None else None
    attn_caches = cache["attn"] if cache is not None else None
    layers_main = jax.tree_util.tree_map(
        lambda a: a[:n_main].reshape(n_super, period, *a.shape[1:]),
        mp["layers"])
    layers_tail = jax.tree_util.tree_map(lambda a: a[n_main:], mp["layers"])

    def super_body(carry, xs):
        h, aux, mstates, li, acaches, si = carry
        blk = xs
        (h, aux, mstates, li), _ = jax.lax.scan(
            mamba_body, (h, aux, mstates, li), blk)
        attn_cache = _index_cache(acaches, si) if acaches is not None \
            else None
        xcat = jnp.concatenate([h, emb0], axis=-1)
        xcat = rms_norm(xcat, mp["shared_ln"])
        out, new_ac = attn_forward(
            shared, xcat, positions, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
            rope_theta=cfg.rope_theta, causal=True,
            cache=attn_cache, cache_index=index)
        if acaches is not None:
            acaches = _update_cache(acaches, new_ac, si)
        h = h + out
        h = h + mlp_forward(mp["shared_mlp"],
                            rms_norm(h, mp["shared_ln2"]), cfg.mlp_kind)
        return (h, aux, mstates, li, acaches, si + 1), None

    carry0 = (h, jnp.asarray(0.0, jnp.float32), cache_mamba,
              jnp.asarray(0, jnp.int32), attn_caches,
              jnp.asarray(0, jnp.int32))
    (h, aux, new_cm, li, new_attn, _), _ = scan_or_loop(
        super_body, carry0, layers_main, cfg.scan_layers, n_super)
    if n - n_main:
        (h, aux, new_cm, _), _ = scan_or_loop(
            mamba_body, (h, aux, new_cm, li), layers_tail,
            cfg.scan_layers, n - n_main)
    new_cache = None
    if cache is not None:
        new_cache = {"mamba": new_cm, "attn": new_attn}
    return h, aux, new_cache


def _embed_inputs(mp, cfg: ModelConfig, tokens, vision_embeds, positions):
    h = jnp.take(mp["embed"], tokens, axis=0)
    if cfg.family == "vlm" and vision_embeds is not None:
        v = qmatmul(vision_embeds, mp["vision_proj"])
        h = jnp.concatenate([v.astype(h.dtype), h], axis=1)
    b, s, _ = h.shape
    if positions is None:
        pos1 = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        positions = jnp.stack([pos1] * 3, axis=-1) if cfg.mrope else pos1
    return h.astype(_cdtype(cfg)), positions


def forward(params, cfg: ModelConfig, tokens, *, vision_embeds=None,
            positions=None, cache=None, index=None):
    """Returns (logits, aux, new_cache)."""
    mp = shard_params_tree(prepare_params(params, _cdtype(cfg)))
    h, positions = _embed_inputs(mp, cfg, tokens, vision_embeds, positions)
    h = constraint(h, "batch", None, None)
    emb0 = h
    if cfg.family in ("dense", "moe", "vlm"):
        h, aux, new_cache = _walk_dense(mp, cfg, h, positions, cache, index)
    elif cfg.family == "ssm":
        h, aux, new_cache = _walk_ssm(mp, cfg, h, cache, index)
    elif cfg.family == "hybrid":
        h, aux, new_cache = _walk_hybrid(mp, cfg, h, emb0, positions, cache,
                                         index)
    else:
        raise ValueError(cfg.family)
    h = rms_norm(h, mp["final_norm"])
    head = mp["lm_head"] if "lm_head" in mp else mp["embed"].T
    logits = qmatmul(h, head).astype(jnp.float32)
    logits = softcap(logits, cfg.logit_softcap)
    logits = constraint(logits, "batch", None, "vocab")
    return logits, aux, new_cache


# ---------------------------------------------------------------------------
# caches (decode)
# ---------------------------------------------------------------------------

def make_kv_cache(cfg: ModelConfig, stack: int, batch: int,
                  max_len: int) -> Dict:
    """One stacked K/V cache at ``cfg.kv_cache_bits`` precision.

    <32 bits stores quantized-at-rest entries (int8, or int4 nibble-packed
    along the head dim) plus per-token/head scales; see models.attention."""
    dh, kv = cfg.head_dim, cfg.n_kv_heads
    shape = (stack, batch, max_len, kv, dh)
    bits = cfg.kv_cache_bits
    if bits == 8:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1], jnp.float32),
                "v_scale": jnp.zeros(shape[:-1], jnp.float32)}
    if bits == 4:
        assert dh % 2 == 0, f"int4 KV cache needs even head_dim, got {dh}"
        pshape = shape[:-1] + (dh // 2,)
        return {"k": jnp.zeros(pshape, jnp.uint8),
                "v": jnp.zeros(pshape, jnp.uint8),
                "k_scale": jnp.zeros(shape[:-1], jnp.float32),
                "v_scale": jnp.zeros(shape[:-1], jnp.float32)}
    dt = _cdtype(cfg)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def _walk_cache_tree(cache, kv_fn, leaf_fn):
    """Apply ``kv_fn`` to KV sub-dicts ({"k","v",...} leaves with a time
    axis) and ``leaf_fn`` to recurrent leaves (no time axis) of a stacked
    decode-cache tree (works on arrays or ShapeDtypeStructs)."""
    if isinstance(cache, dict):
        if "k" in cache and "v" in cache:
            return kv_fn(cache)
        return {k: _walk_cache_tree(v, kv_fn, leaf_fn)
                for k, v in cache.items()}
    return leaf_fn(cache)


def rebatch_cache_tree(cache, n_slots: int, time_len: int):
    """Zero contiguous decode cache re-sized to ``n_slots`` slots of
    ``time_len`` positions, mirroring ``cache``'s tree/dtypes (which may
    come from ``jax.eval_shape`` — no allocation until here)."""
    return _walk_cache_tree(
        cache,
        lambda node: {n: jnp.zeros((l.shape[0], n_slots, time_len,
                                    *l.shape[3:]), l.dtype)
                      for n, l in node.items()},
        lambda l: jnp.zeros((l.shape[0], n_slots, *l.shape[2:]), l.dtype))


def paginate_cache_tree(cache, n_slots: int, n_pages: int, page_size: int,
                        nb: int):
    """Zero *paged* decode cache mirroring contiguous ``cache``.

    Every KV sub-dict becomes ``{"pages", "table"}``: pool leaves trade the
    per-slot (B, T) layout for a global (n_pages, page_size) page axis —
    same storage dtypes, so int8/int4-at-rest formats carry over — and the
    (stack, n_slots, nb) block table starts all-trash (page 0 is reserved;
    a slot's block b maps to the pool page holding its tokens
    [b*page_size, (b+1)*page_size)).  Recurrent leaves (no time axis) are
    plain re-batched rows, as in :func:`rebatch_cache_tree`."""
    return _walk_cache_tree(
        cache,
        lambda node: {
            "pages": {n: jnp.zeros((l.shape[0], n_pages, page_size,
                                    *l.shape[3:]), l.dtype)
                      for n, l in node.items()},
            "table": jnp.zeros((node["k"].shape[0], n_slots, nb),
                               jnp.int32)},
        lambda l: jnp.zeros((l.shape[0], n_slots, *l.shape[2:]), l.dtype))


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    dt = _cdtype(cfg)
    if cfg.family in ("dense", "moe", "vlm"):
        return make_kv_cache(cfg, cfg.n_layers, batch, max_len)
    if cfg.family == "ssm":
        st = rwkv6_init_state(batch, cfg.d_model, cfg.n_heads, dt)
        return jax.tree_util.tree_map(
            lambda a: jnp.zeros((cfg.n_layers, *a.shape), a.dtype), st)
    if cfg.family == "hybrid":
        mst = mamba2_init_state(batch, cfg.d_model, cfg.ssm_state,
                                cfg.ssm_expand, cfg.ssm_headdim, dtype=dt)
        mamba = jax.tree_util.tree_map(
            lambda a: jnp.zeros((cfg.n_layers, *a.shape), a.dtype), mst)
        n_super = cfg.n_layers // cfg.hybrid_attn_every
        return {"mamba": mamba,
                "attn": make_kv_cache(cfg, n_super, batch, max_len)}
    raise ValueError(cfg.family)


def decode_positions(index, batch: int, seq: int = 1) -> jnp.ndarray:
    """(B, S) absolute positions from a scalar or per-slot (B,) index."""
    idx = jnp.asarray(index, jnp.int32)
    base = idx[:, None] if idx.ndim else idx
    return jnp.broadcast_to(base + jnp.arange(seq, dtype=jnp.int32)[None, :],
                            (batch, seq)).astype(jnp.int32)


def decode_step(params, cfg: ModelConfig, tokens, cache, index):
    """One-token step. tokens: (B, 1); index: () int32 current length, or a
    per-slot (B,) vector of lengths (continuous batching)."""
    b = tokens.shape[0]
    pos1 = decode_positions(index, b)
    positions = jnp.stack([pos1] * 3, axis=-1) if cfg.mrope else pos1
    logits, aux, new_cache = forward(params, cfg, tokens,
                                     positions=positions, cache=cache,
                                     index=index)
    return logits[:, -1], new_cache


def verify_step(params, cfg: ModelConfig, tokens, cache, index):
    """W-token decode forward for speculative verification.

    ``tokens``: (B, W) — a slot's last accepted token followed by its
    draft proposals; ``index``: scalar or per-slot (B,) fill levels.
    Returns the full (B, W, V) logits (the verifier needs every
    position's next-token distribution, not just the last) and the
    cache with all W positions (re)written at full precision."""
    b, w = tokens.shape
    pos = decode_positions(index, b, w)
    positions = jnp.stack([pos] * 3, axis=-1) if cfg.mrope else pos
    logits, aux, new_cache = forward(params, cfg, tokens,
                                     positions=positions, cache=cache,
                                     index=index)
    return logits, new_cache


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    """Next-token cross entropy (+ MoE aux).  batch: tokens, labels, [mask]."""
    logits, aux, _ = forward(params, cfg, batch["tokens"],
                             vision_embeds=batch.get("vision_embeds"),
                             positions=batch.get("positions"))
    labels = batch["labels"]
    if cfg.family == "vlm" and batch.get("vision_embeds") is not None:
        logits = logits[:, -labels.shape[1]:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    mask = batch.get("mask")
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = float(nll.size)
    ce = jnp.sum(nll) / denom
    return ce + 0.01 * aux, dict(ce=ce, aux=aux)
