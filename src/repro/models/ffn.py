"""Feed-forward blocks: SwiGLU / GELU MLPs (quantizable)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..dist.sharding import constraint
from .common import make_weight, qmatmul


def init_mlp(key, d_model: int, d_ff: int, qc, kind: str = "swiglu",
             dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w_gate": make_weight(ks[0], (d_model, d_ff), qc, dtype=dtype),
            "w_up": make_weight(ks[1], (d_model, d_ff), qc, dtype=dtype),
            "w_down": make_weight(ks[2], (d_ff, d_model), qc, dtype=dtype),
        }
    return {  # plain 2-layer MLP (gelu / relu)
        "w_in": make_weight(ks[0], (d_model, d_ff), qc, dtype=dtype),
        "w_out": make_weight(ks[1], (d_ff, d_model), qc, dtype=dtype),
    }


def mlp_forward(p: Dict, x: jnp.ndarray, kind: str = "swiglu") -> jnp.ndarray:
    if kind == "swiglu":
        h = jax.nn.silu(qmatmul(x, p["w_gate"])) * qmatmul(x, p["w_up"])
        h = constraint(h, "batch", None, "ff")
        return qmatmul(h, p["w_down"])
    act = jax.nn.gelu if kind == "gelu" else jax.nn.relu
    h = act(qmatmul(x, p["w_in"]))
    h = constraint(h, "batch", None, "ff")
    return qmatmul(h, p["w_out"])
