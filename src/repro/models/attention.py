"""Grouped-query attention with RoPE/M-RoPE, sliding windows, softcaps,
and a KV-cache decode path.  Pure functions over plain arrays; sharding is
annotated with logical axes (heads on the 'model' mesh axis)."""
from __future__ import annotations

import contextlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.quantize import pack_int4, unpack_int4
from ..dist.sharding import constraint
from ..kernels.paged_attention import paged_attention
from ..kernels.pallas_utils import fit_block
from ..kernels.ref import paged_attention_ref
from .common import qmatmul
from .common import softcap as _softcap
from .rope import apply_rope, mrope_angles, rope_angles

NEG_INF = -2.0e38

# Blockwise-attention dispatch knobs.  The dry-run calibration pass lowers
# with min_elems=inf (dense) so XLA cost analysis sees un-scanned bodies.
ATTN_OPTS = {"min_elems": 4096 * 2048, "q_block": 512, "kv_block": 1024}


def init_attn(key, d_model: int, n_heads: int, n_kv: int, d_head: int,
              qc, make_weight, qkv_bias: bool = False,
              d_model_in: Optional[int] = None, dtype=jnp.float32) -> Dict:
    din = d_model_in or d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": make_weight(ks[0], (din, n_heads * d_head), qc, dtype=dtype),
        "wk": make_weight(ks[1], (din, n_kv * d_head), qc, dtype=dtype),
        "wv": make_weight(ks[2], (din, n_kv * d_head), qc, dtype=dtype),
        "wo": make_weight(ks[3], (n_heads * d_head, d_model), qc, dtype=dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), dtype)
        p["bk"] = jnp.zeros((n_kv * d_head,), dtype)
        p["bv"] = jnp.zeros((n_kv * d_head,), dtype)
    return p


def _split_heads(x, n, d):
    return x.reshape(*x.shape[:-1], n, d)


# ---------------------------------------------------------------------------
# quantized-at-rest KV cache
# ---------------------------------------------------------------------------
#
# K/V are quantized ONCE when written (per-token/per-head dynamic scales,
# KIVI-style) and dequantized in-graph per attention call, so repeated
# decode steps never re-round already-stored entries.  int8 stores one
# value per byte; int4 nibble-packs pairs along the head dim.

def cache_bits(cache) -> int:
    """Storage precision of a KV cache dict: 32 (float), 8, or 4.

    Accepts either a contiguous cache ``{"k", "v", ...}`` or a paged one
    ``{"pages": {"k", ...}, "table": ...}``."""
    if "pages" in cache:
        cache = cache["pages"]
    dt = cache["k"].dtype
    if dt == jnp.int8:
        return 8
    if dt == jnp.uint8:
        return 4
    return 32


def quantize_kv(x: jnp.ndarray, bits: int):
    """(B, S, KV, dh) float -> (quantized, scale(B, S, KV)).

    int8: one int8 per element; int4: two's-complement nibbles packed in
    uint8 pairs along dh (dh must be even)."""
    lim = 127.0 if bits == 8 else 7.0
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / lim
    s = jnp.maximum(s, 1e-6)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]), -lim, lim)
    if bits == 8:
        return q.astype(jnp.int8), s
    return pack_int4(q, axis=-1), s


def dequantize_kv(qx: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    """Inverse of :func:`quantize_kv` (int8 or packed-int4 -> ``dtype``)."""
    if qx.dtype == jnp.int8:
        return qx.astype(dtype) * scale[..., None].astype(dtype)
    w = unpack_int4(qx, axis=-1)
    return w.astype(dtype) * scale[..., None].astype(dtype)


def _cache_write(buf, update, idx, axis: int = 1):
    """Write ``update`` into ``buf`` at offset ``idx`` along ``axis``.

    Scalar ``idx`` writes every batch row at the same offset (legacy
    whole-batch decode); a (B,) vector writes each row at its own offset
    (continuous batching: dim 0 of both operands is the batch/slot dim)."""
    if jnp.ndim(idx) == 1:
        return jax.vmap(
            lambda b, u, i: jax.lax.dynamic_update_slice_in_dim(
                b, u, i, axis=axis - 1))(buf, update, idx)
    return jax.lax.dynamic_update_slice_in_dim(buf, update, idx, axis=axis)


# ---------------------------------------------------------------------------
# paged cache (block-granular pool + per-slot block tables)
# ---------------------------------------------------------------------------
#
# A paged layer cache is ``{"pages": {k, v[, k_scale, v_scale]}, "table"}``:
# pool leaves carry a global page axis (P, page, KV, ...) instead of the
# per-slot (B, T, ...) layout, and ``table`` (B, nb) maps each slot's
# block b to the pool page holding its tokens [b*page, (b+1)*page).  Page 0
# is reserved as the trash page: parked slots and unallocated table entries
# point at it, and everything routed there stays masked by the per-slot
# fill level.  The storage format (int8 / nibble-packed int4 + per-token
# scales) is identical to the contiguous cache — paging changes residency,
# not representation.

def page_coords(table, idx, seq: int, page: int):
    """Slot-relative write positions -> (pool page ids, in-page offsets).

    ``table``: (B, nb) block table; ``idx``: scalar or (B,) fill level.
    Returns two (B, seq) int32 arrays for positions idx .. idx+seq-1.
    Positions past the table end clamp into the last block (jnp gather
    semantics); callers only ever send masked scratch writes there."""
    b = table.shape[0]
    idx = jnp.asarray(idx, jnp.int32)
    base = idx[:, None] if jnp.ndim(idx) == 1 else idx
    pos = jnp.broadcast_to(base + jnp.arange(seq, dtype=jnp.int32), (b, seq))
    pids = jnp.take_along_axis(table, pos // page, axis=1)
    return pids, pos % page


def paged_gather(pool_leaf, table):
    """(P, page, ...) pool leaf + (B, nb) table -> contiguous (B, T, ...)
    per-slot view (T = nb * page), token order preserved."""
    g = jnp.take(pool_leaf, table, axis=0)
    return g.reshape(g.shape[0], g.shape[1] * g.shape[2], *g.shape[3:])


# ---------------------------------------------------------------------------
# decode-attention dispatch (mirrors models.common.matmul_backend)
# ---------------------------------------------------------------------------
#
# ``gather`` is the legacy read side above (paged_gather / _cache_write +
# in-graph dequant + attention_core); ``fused`` walks the block table
# inside the Pallas kernel so neither the contiguous (B, T, ...) KV view
# nor the f32 KV tree is ever materialized; ``ref`` is the kernel's
# pure-jnp oracle.  Both non-gather backends are decode-only (one query
# token, causal self-attention) — every other shape falls back to gather
# in-trace, which the graph lint flags under a fused engine.

PAGED_ATTN_BACKENDS = ("gather", "fused", "ref")
_PA_BACKEND_STACK = ["gather"]


@contextlib.contextmanager
def paged_attn_backend(name: str):
    """Ambient decode-attention backend for :func:`paged_attn`
    (trace-time, like :func:`repro.models.common.matmul_backend`)."""
    if name not in PAGED_ATTN_BACKENDS:
        raise ValueError(f"unknown paged-attention backend {name!r}; "
                         f"choose from {PAGED_ATTN_BACKENDS}")
    _PA_BACKEND_STACK.append(name)
    try:
        yield
    finally:
        _PA_BACKEND_STACK.pop()


def current_paged_attn_backend() -> str:
    return _PA_BACKEND_STACK[-1]


def paged_attn(q, store: Dict, table, kv_len, *, window=0,
               attn_softcap: float = 0.0,
               backend: Optional[str] = None) -> jnp.ndarray:
    """One decode step of attention straight over a (quantized) page pool.

    q: (B, 1, H, dh) roped queries; ``store``: pool leaves
    ``{"k", "v"[, "k_scale", "v_scale"]}`` (P, page, KV, ...);
    ``table``: (B, nb); ``kv_len``: (B,) fill levels *including* the
    token just written.  Returns (B, 1, H, dh) in q's dtype.  The
    contiguous cache is served through the same entry by viewing each
    slot's (T, ...) row as pages (see ``attn_forward``)."""
    backend = backend or current_paged_attn_backend()
    b, s, h, dh = q.shape
    kv = store["k"].shape[2]
    qg = q.reshape(b, kv, h // kv, dh)
    win = None if isinstance(window, int) and window == 0 else \
        jnp.asarray(window, jnp.int32)
    args = (qg, store["k"], store["v"], store.get("k_scale"),
            store.get("v_scale"), table, kv_len)
    if backend == "fused":
        out = paged_attention(*args, window=win, softcap=attn_softcap)
    elif backend == "ref":
        out = paged_attention_ref(*args, window=win, softcap=attn_softcap)
    else:
        raise ValueError(f"paged_attn executes 'fused' or 'ref', "
                         f"got {backend!r}")
    return out.reshape(b, s, h, dh).astype(q.dtype)


def _as_pool(leaf, page: int):
    """(B, T, ...) contiguous cache leaf -> (B*(T//page), page, ...) pool
    view (a free row-major reshape — paging as a *view*, not a copy)."""
    b, t = leaf.shape[0], leaf.shape[1]
    return leaf.reshape(b * (t // page), page, *leaf.shape[2:])


def _mask_for(q_pos, kv_pos, causal, window, kv_len):
    """(B, S, T) boolean mask from position arrays (window may be traced)."""
    mask = jnp.ones((q_pos.shape[0], q_pos.shape[-1], kv_pos.shape[-1]), bool)
    if causal:
        mask &= kv_pos[:, None, :] <= q_pos[:, :, None]
    if isinstance(window, (int, float)):
        if window > 0:
            mask &= kv_pos[:, None, :] > q_pos[:, :, None] - window
    else:
        wm = kv_pos[:, None, :] > q_pos[:, :, None] - window
        mask &= jnp.where(window > 0, wm, True)
    if kv_len is not None:
        mask &= kv_pos[:, None, :] < kv_len[:, None, None]
    return mask


def blockwise_attention_core(q, k, v, q_pos, kv_pos, *, causal=True,
                             window=0, attn_softcap=0.0, kv_len=None,
                             q_block: int = 512,
                             kv_block: int = 1024) -> jnp.ndarray:
    """Flash-style memory-efficient attention: never materializes (S, T).

    Outer scan over query blocks, inner scan over KV blocks with running
    (max, denom, acc) — O(q_block * kv_block) live scores.  Differentiable
    (autodiff through the scans; layer-level remat bounds residuals).
    """
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    q_block = min(q_block, s)
    kv_block = min(kv_block, t)
    assert s % q_block == 0 and t % kv_block == 0, (s, t, q_block, kv_block)
    nq, nk = s // q_block, t // kv_block

    qg = q.reshape(b, nq, q_block, kv, g, dh)
    qp = q_pos.reshape(b, nq, q_block)
    kb = k.reshape(b, nk, kv_block, kv, dh)
    vb = v.reshape(b, nk, kv_block, kv, dh)
    kp = kv_pos.reshape(b, nk, kv_block)
    scale = 1.0 / jnp.sqrt(dh)

    def q_step(_, q_in):
        qi, qpi = q_in                       # (b,qb,kv,g,dh), (b,qb)

        def kv_step(carry, kv_in):
            m, l, acc = carry
            ki, vi, kpi = kv_in              # (b,kb,kv,dh), ..., (b,kb)
            sc = jnp.einsum("bqkgd,btkd->bkgqt", qi, ki,
                            preferred_element_type=jnp.float32) * scale
            if attn_softcap:
                sc = _softcap(sc, attn_softcap)
            msk = _mask_for(qpi, kpi, causal, window, kv_len)
            sc = jnp.where(msk[:, None, None, :, :], sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(vi.dtype), vi)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, kv, g, q_block, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step),
            (m0, l0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
             jnp.moveaxis(kp, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out = jnp.moveaxis(out, 3, 1)        # (b, qb, kv, g, dh)
        return None, out

    _, outs = jax.lax.scan(
        q_step, None,
        (jnp.moveaxis(qg, 1, 0), jnp.moveaxis(qp, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1)           # (b, nq, qb, kv, g, dh)
    return out.reshape(b, s, h, dh).astype(q.dtype)


def attention_core(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   q_pos: jnp.ndarray, kv_pos: jnp.ndarray,
                   *, causal: bool = True, window: int = 0,
                   attn_softcap: float = 0.0,
                   kv_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """q: (B,S,H,dh), k/v: (B,T,KV,dh), positions: (B,S)/(B,T) -> (B,S,H,dh).

    ``window > 0`` restricts attention to the last ``window`` positions
    (Gemma-2 local layers); ``kv_len`` masks cache tails during decode.
    Large (S x T) problems dispatch to the blockwise flash-style core.
    """
    qb, kb = ATTN_OPTS["q_block"], ATTN_OPTS["kv_block"]
    if q.shape[1] * k.shape[1] > ATTN_OPTS["min_elems"] and \
            q.shape[1] % qb == 0 and k.shape[1] % kb == 0:
        return blockwise_attention_core(
            q, k, v, q_pos, kv_pos, causal=causal, window=window,
            attn_softcap=attn_softcap, kv_len=kv_len,
            q_block=qb, kv_block=kb)
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(dh).astype(scores.dtype)
    if attn_softcap:
        scores = _softcap(scores, attn_softcap)
    mask = _mask_for(q_pos, kv_pos, causal, window, kv_len)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, dh)


def attn_forward(p: Dict, x: jnp.ndarray, positions: jnp.ndarray, *,
                 n_heads: int, n_kv: int, d_head: int, rope_theta: float,
                 causal: bool = True, window: int = 0,
                 attn_softcap: float = 0.0, mrope: bool = False,
                 x_kv: Optional[jnp.ndarray] = None,
                 kv_positions: Optional[jnp.ndarray] = None,
                 cache: Optional[Dict] = None,
                 cache_index: Optional[jnp.ndarray] = None,
                 ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full attention sub-layer: projections + rope + core + output proj.

    With ``cache`` given, appends K/V at ``cache_index`` and attends over the
    cache (decode / incremental prefill).  ``x_kv`` enables cross-attention.
    """
    xk_src = x_kv if x_kv is not None else x
    q = qmatmul(x, p["wq"])
    k = qmatmul(xk_src, p["wk"])
    v = qmatmul(xk_src, p["wv"])
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = _split_heads(q, n_heads, d_head)
    k = _split_heads(k, n_kv, d_head)
    v = _split_heads(v, n_kv, d_head)
    q = constraint(q, "batch", None, "heads", None)
    k = constraint(k, "batch", None, "kv_heads", None)

    if kv_positions is None:
        kv_positions = positions
    if x_kv is None:  # rope only for self-attention
        if mrope:
            ang_q = mrope_angles(positions, d_head, rope_theta)
            ang_k = mrope_angles(kv_positions, d_head, rope_theta)
            q_pos = positions[..., 0]
            kv_pos = kv_positions[..., 0]
        else:
            ang_q = rope_angles(positions, d_head, rope_theta)
            ang_k = rope_angles(kv_positions, d_head, rope_theta)
            q_pos, kv_pos = positions, kv_positions
        q = apply_rope(q, ang_q)
        k = apply_rope(k, ang_k)
    else:
        q_pos, kv_pos = positions, kv_positions

    new_cache = None
    kv_len = None
    out = None
    if cache is not None:
        idx = cache_index  # (): shared fill level, or (B,): per-slot levels
        kq, vq = k, v
        bits = cache_bits(cache)
        # fused / ref decode attention reads the cache *in its stored
        # representation* (one query token, causal self-attention only);
        # every other shape keeps the gather read side below.
        pa = current_paged_attn_backend()
        decode_only = (pa != "gather" and x.shape[1] == 1
                       and x_kv is None and causal)
        if bits < 32:
            # quantized-at-rest cache (int8 / packed int4 with per-token/
            # head dynamic scales): each written position is rounded exactly
            # once; reads dequantize in-graph, so HBM traffic drops 2x/4x
            # at ~3% metadata overhead without compounding rounding error.
            kq, ks_sc = quantize_kv(k, bits)
            vq, vs_sc = quantize_kv(v, bits)
        if "table" in cache:
            # paged: scatter the new tokens into their slots' pool pages,
            # then gather each slot's block list back into a contiguous
            # (B, T) view — token order matches the contiguous cache, so
            # attention (and therefore decoding) is bit-identical.
            table = cache["table"]
            store = cache["pages"]
            pids, offs = page_coords(table, idx, k.shape[1],
                                     store["k"].shape[1])
            new_store = dict(store,
                             k=store["k"].at[pids, offs].set(kq),
                             v=store["v"].at[pids, offs].set(vq))
            if bits < 32:
                new_store.update(
                    k_scale=store["k_scale"].at[pids, offs].set(ks_sc),
                    v_scale=store["v_scale"].at[pids, offs].set(vs_sc))
            new_cache = dict(cache, pages=new_store)
            if decode_only:
                kv_len = jnp.broadcast_to(
                    jnp.asarray(idx, jnp.int32) + 1, (x.shape[0],))
                out = paged_attn(q, new_store, table, kv_len,
                                 window=window, attn_softcap=attn_softcap,
                                 backend=pa)
            else:
                ck = paged_gather(new_store["k"], table)
                cv = paged_gather(new_store["v"], table)
                if bits < 32:
                    k = dequantize_kv(ck, paged_gather(new_store["k_scale"],
                                                       table), q.dtype)
                    v = dequantize_kv(cv, paged_gather(new_store["v_scale"],
                                                       table), q.dtype)
                else:
                    k, v = ck, cv
        else:
            if bits < 32:
                cks = _cache_write(cache["k_scale"], ks_sc, idx)
                cvs = _cache_write(cache["v_scale"], vs_sc, idx)
            ck = _cache_write(cache["k"], kq, idx)
            cv = _cache_write(cache["v"], vq, idx)
            new_cache = dict(cache, k=ck, v=cv)
            if bits < 32:
                new_cache.update(k_scale=cks, v_scale=cvs)
            if decode_only:
                # serve the contiguous cache through the same kernel by
                # viewing each slot's (T, ...) row as T//page pages with
                # an identity block table (free reshape, no trash page)
                t = ck.shape[1]
                page = fit_block(min(128, t), t, 1)
                pool = {"k": _as_pool(ck, page), "v": _as_pool(cv, page)}
                if bits < 32:
                    pool.update(k_scale=_as_pool(cks, page),
                                v_scale=_as_pool(cvs, page))
                ident = jnp.arange(
                    x.shape[0] * (t // page),
                    dtype=jnp.int32).reshape(x.shape[0], t // page)
                kv_len = jnp.broadcast_to(
                    jnp.asarray(idx, jnp.int32) + 1, (x.shape[0],))
                out = paged_attn(q, pool, ident, kv_len, window=window,
                                 attn_softcap=attn_softcap, backend=pa)
            elif bits < 32:
                k = dequantize_kv(ck, cks, q.dtype)
                v = dequantize_kv(cv, cvs, q.dtype)
            else:
                k, v = ck, cv
        if out is None:
            t = ck.shape[1]
            kv_pos = jnp.broadcast_to(jnp.arange(t)[None, :],
                                      (x.shape[0], t))
            kv_len = jnp.broadcast_to(jnp.asarray(idx) + x.shape[1],
                                      (x.shape[0],))

    if out is None:
        out = attention_core(q, k, v, q_pos, kv_pos,
                             causal=causal and x_kv is None,
                             window=window, attn_softcap=attn_softcap,
                             kv_len=kv_len)
    out = out.reshape(*x.shape[:-1], n_heads * d_head)
    return qmatmul(out, p["wo"]), new_cache
