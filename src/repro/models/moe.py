"""Top-k token-choice Mixture-of-Experts with sort-based ragged dispatch.

Tokens are sorted by expert id and pushed through ``jax.lax.ragged_dot``
against the stacked expert weights — no dense (T, E, C) dispatch tensors,
no capacity drops.  Expert weights carry their in-expert TP sharding
('expert' rule: d_ff on the model axis); true cross-device EP with
all-to-all is a perf variant explored in EXPERIMENTS.md §Perf.

The router is kept in float32 and outside BWQ quantization (DESIGN.md §5).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import batch_axes, constraint, get_mesh, spec
from .common import make_weight, qdense, qmatmul


@jax.custom_vjp
def grouped_matmul(x, w, group_sizes):
    """y[M,N] = per-group x[M,K] @ w[g,K,N] (tokens sorted by group).

    jax.lax.ragged_dot's default VJP densifies to (g, M, K) tensors —
    catastrophic for MoE training memory.  This custom VJP keeps both
    directions ragged: dx is another ragged_dot, dw is the
    ragged-*contracting* mode of ragged_dot_general (per-group outer
    products, no densification).
    """
    return jax.lax.ragged_dot(x, w, group_sizes)


def _gm_fwd(x, w, group_sizes):
    return grouped_matmul(x, w, group_sizes), (x, w, group_sizes)


def _gm_bwd(res, dy):
    x, w, gs = res
    dx = jax.lax.ragged_dot(dy, jnp.swapaxes(w, 1, 2), gs)
    if hasattr(jax.lax, "RaggedDotDimensionNumbers"):
        dnums = jax.lax.RaggedDotDimensionNumbers(
            dot_dimension_numbers=(((0,), (0,)), ((), ())),
            lhs_ragged_dimensions=[0], rhs_group_dimensions=[])
        dw = jax.lax.ragged_dot_general(x, dy, gs, dnums)
    else:
        # Older jax has no ragged-contracting mode: mask tokens into their
        # group via one-hot and contract.  Materializes (T, E, K) — fine at
        # the small-scale sizes that run on these jax versions.
        e = w.shape[0]
        gid = jnp.repeat(jnp.arange(e), gs, total_repeat_length=x.shape[0])
        onehot = jax.nn.one_hot(gid, e, dtype=x.dtype)      # (T, E)
        xg = onehot[:, :, None] * x[:, None, :]             # (T, E, K)
        dw = jnp.einsum("tek,tn->ekn", xg, dy)
    return dx.astype(x.dtype), dw.astype(w.dtype), None


grouped_matmul.defvjp(_gm_fwd, _gm_bwd)

# Implementation selector.  'ragged' (jax.lax.ragged_dot + custom VJP) is
# exact/no-drop but XLA lowers it densely to (E, M, K) tensors on backends
# without native ragged-dot support — prohibitive at pod scale.  'capacity'
# is the GShard-style fixed-capacity path: a scan over experts with static
# per-expert capacity; tokens beyond capacity are dropped (standard
# capacity-factor semantics).  The dry-run and the at-scale launcher use
# 'capacity'; small-scale exact runs use 'ragged'.
GROUPED_IMPL = {"impl": "ragged", "capacity_factor": 2.0}


def grouped_matmul_capacity(x, w, group_sizes, capacity: int):
    """Capacity-bounded grouped matmul over sorted tokens.

    x: (M, K) tokens sorted by group; w: (E, K, N) — a plain array or a
    scan-sliceable quantized representation (ServingWeight /
    FakeQuantTensor with E leading): the scan over experts slices one
    expert's (packed) weight per step and ``qmatmul`` executes it, so
    packed experts run on the compressed format.  Returns (M, N) with
    zeros for tokens past their group's capacity (dropped).
    """
    m, k = x.shape
    e, n = w.shape[0], w.shape[-1]
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(group_sizes)[:-1].astype(jnp.int32)])
    x_pad = jnp.concatenate([x, jnp.zeros((capacity, k), x.dtype)], axis=0)

    def body(y, ins):
        w_e, start, size = ins
        xs = jax.lax.dynamic_slice(x_pad, (start, 0), (capacity, k))
        mask = (jnp.arange(capacity) < size)[:, None].astype(x.dtype)
        ys = qmatmul(xs * mask, w_e) * mask
        idx = start + jnp.arange(capacity)
        y = y.at[idx].add(ys, mode="drop")
        return y, None

    y0 = jnp.zeros((m + capacity, n), x.dtype)
    y, _ = jax.lax.scan(body, y0, (w, starts, group_sizes))
    return y[:m]


def _capacity(m: int, e: int) -> int:
    """Per-expert token capacity: factor * mean load, rounded up to 8."""
    cap = int(GROUPED_IMPL["capacity_factor"] * m / e + 0.999)
    return max(8, min(m, -(-cap // 8) * 8))


def _grouped(x, w, group_sizes):
    """Grouped dispatch over possibly-quantized expert weights.

    The capacity scan consumes quantized experts natively (one packed
    expert sliced per scan step); ``ragged_dot`` needs a dense (E, K, N)
    operand, so that path dequantizes through the sanctioned
    ``common.qdense`` entry."""
    from ..core.bitrep import QuantizedTensor
    if isinstance(w, QuantizedTensor):
        w = qdense(w, x.dtype)     # bit axis leads: not scan-sliceable
    if GROUPED_IMPL["impl"] == "capacity":
        return grouped_matmul_capacity(x, w, group_sizes,
                                       _capacity(x.shape[0], w.shape[0]))
    return grouped_matmul(x, qdense(w, x.dtype), group_sizes)


def init_moe(key, d_model: int, d_ff: int, n_experts: int, top_k: int,
             qc, n_shared: int = 0, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 5)
    p = {
        "router_w": jax.random.normal(ks[0], (d_model, n_experts),
                                      jnp.float32) * 0.02,
        "expert_gate": make_weight(ks[1], (n_experts, d_model, d_ff), qc,
                                   dtype=dtype),
        "expert_up": make_weight(ks[2], (n_experts, d_model, d_ff), qc,
                                 dtype=dtype),
        "expert_down": make_weight(ks[3], (n_experts, d_ff, d_model), qc,
                                   dtype=dtype),
    }
    if n_shared:
        p["shared_gate"] = make_weight(ks[4], (d_model, n_shared * d_ff), qc,
                                       dtype=dtype)
        key2 = jax.random.fold_in(ks[4], 1)
        p["shared_up"] = make_weight(key2, (d_model, n_shared * d_ff), qc,
                                     dtype=dtype)
        key3 = jax.random.fold_in(ks[4], 2)
        p["shared_down"] = make_weight(key3, (n_shared * d_ff, d_model), qc,
                                       dtype=dtype)
    return p


def moe_forward(p: Dict, x: jnp.ndarray, top_k: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).

    Under an active mesh this dispatches to the shard_map path: routing +
    sort stay LOCAL to each data shard (a global argsort under pjit would
    gather every token to every device), expert FFNs run with in-expert TP
    over 'model', partial outputs psum over 'model'.
    """
    mesh = get_mesh()
    if mesh is not None and mesh.devices.size > 1:
        return _moe_forward_sharded(p, x, top_k, mesh)
    return _moe_forward_local(p, x, top_k)


def _moe_forward_local(p: Dict, x: jnp.ndarray, top_k: int
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, d = x.shape
    e = p["router_w"].shape[-1]
    xt = x.reshape(b * s, d)
    logits = xt.astype(jnp.float32) @ p["router_w"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)      # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    t = b * s
    flat_expert = expert_idx.reshape(-1)                     # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t), top_k)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert)                         # stable
    tok_sorted = flat_token[order]
    xs = jnp.take(xt, tok_sorted, axis=0)                    # (T*k, D)
    group_sizes = jnp.bincount(flat_expert, length=e).astype(jnp.int32)

    gate = _grouped(xs, p["expert_gate"], group_sizes)
    up = _grouped(xs, p["expert_up"], group_sizes)
    h = jax.nn.silu(gate) * up
    h = constraint(h, None, "ff")
    ys = _grouped(h, p["expert_down"], group_sizes)      # (T*k, D)

    ys = ys * flat_gate[order][:, None].astype(ys.dtype)
    out = jnp.zeros_like(xt).at[tok_sorted].add(ys)
    out = out.reshape(b, s, d)

    if "shared_gate" in p:
        hs = jax.nn.silu(qmatmul(x, p["shared_gate"])) \
            * qmatmul(x, p["shared_up"])
        out = out + qmatmul(hs, p["shared_down"])

    # load-balance auxiliary loss (Switch-style)
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(expert_idx, e).sum(1) > 0).astype(jnp.float32), 0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out, aux


def _moe_forward_sharded(p: Dict, x: jnp.ndarray, top_k: int, mesh
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """shard_map MoE: per-data-shard routing/sort + in-expert TP on 'model'.

    Expert weights are first constrained to drop their FSDP 'data' dim
    (one per-layer all-gather — ZeRO-3 unshard at use), keeping 'model'
    (d_ff) sharded; inside the shard the ragged grouped matmuls run on
    local tokens only and partial d_model outputs are psum'd over 'model'.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = batch_axes(mesh)
    dpa = dp[0] if len(dp) == 1 else tuple(dp)
    has_model = "model" in mesh.axis_names
    e = p["router_w"].shape[-1]
    model_size = mesh.shape.get("model", 1)
    # TRUE expert parallelism when E divides the model axis: each model
    # rank owns E/model experts outright (weights never gathered); tokens
    # are data-sharded and every rank computes only its experts' share.
    # Otherwise fall back to in-expert tensor parallelism on d_ff.
    ep_mode = has_model and e % model_size == 0

    def reshard(w, spec):
        return jax.lax.with_sharding_constraint(w, NamedSharding(mesh, spec))

    if ep_mode:
        wspec_g = wspec_u = P("model", None, None)
        wspec_d = P("model", None, None)
    else:
        wspec_g = wspec_u = P(None, None, "model" if has_model else None)
        wspec_d = P(None, "model" if has_model else None, None)
    # shard_map needs dense (E, K, N) operands with one spec per array;
    # packed expert execution under shard_map is future work, so quantized
    # experts dequantize here through the sanctioned common.qdense entry.
    wg = reshard(qdense(p["expert_gate"], x.dtype), wspec_g)
    wu = reshard(qdense(p["expert_up"], x.dtype), wspec_u)
    wd = reshard(qdense(p["expert_down"], x.dtype), wspec_d)
    rw = reshard(p["router_w"], P())

    def local_moe(xs, rw, wg, wu, wd):
        b, s, d = xs.shape
        xt = xs.reshape(b * s, d)
        t = b * s
        logits = xt.astype(jnp.float32) @ rw
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
        flat_expert = expert_idx.reshape(-1)
        flat_token = jnp.repeat(jnp.arange(t), top_k)
        flat_gate = gate_vals.reshape(-1)
        order = jnp.argsort(flat_expert)
        tok_sorted = flat_token[order]
        xsrt = jnp.take(xt, tok_sorted, axis=0)
        group_sizes = jnp.bincount(flat_expert, length=e).astype(jnp.int32)
        if ep_mode:
            # compute only this rank's expert range over the sorted tokens
            e_local = wg.shape[0]
            rank = jax.lax.axis_index("model")
            offs = rank * e_local
            gs_local = jax.lax.dynamic_slice(group_sizes, (offs,), (e_local,))
            starts = jnp.concatenate(
                [jnp.zeros((1,), jnp.int32),
                 jnp.cumsum(group_sizes)[:-1].astype(jnp.int32)])
            start0 = jax.lax.dynamic_slice(starts, (offs,), (1,))[0]
            # roll so this rank's tokens start at row 0
            xloc = jnp.roll(xsrt, -start0, axis=0)
            if GROUPED_IMPL["impl"] == "ragged":
                # exact/no-drop EP dispatch (honors the impl flag): append
                # a zero dummy expert whose group absorbs the other ranks'
                # tokens, so every local token is computed regardless of
                # routing skew and the psum over 'model' reassembles the
                # single-device exact output.
                rest = (jnp.asarray(xloc.shape[0], jnp.int32)
                        - jnp.sum(gs_local).astype(jnp.int32))[None]
                gs_ext = jnp.concatenate([gs_local, rest])

                def _ext(w):
                    return jnp.concatenate(
                        [w, jnp.zeros((1,) + w.shape[1:], w.dtype)])

                gate = grouped_matmul(xloc, _ext(wg), gs_ext)
                up = grouped_matmul(xloc, _ext(wu), gs_ext)
                h = jax.nn.silu(gate) * up
                ys = grouped_matmul(h, _ext(wd), gs_ext)
            else:
                cap = _capacity(xt.shape[0] * top_k, e)
                gate = grouped_matmul_capacity(xloc, wg, gs_local, cap)
                up = grouped_matmul_capacity(xloc, wu, gs_local, cap)
                h = jax.nn.silu(gate) * up
                ys = grouped_matmul_capacity(h, wd, gs_local, cap)
            ys = jnp.roll(ys, start0, axis=0)
        else:
            gate = _grouped(xsrt, wg, group_sizes)
            up = _grouped(xsrt, wu, group_sizes)
            h = jax.nn.silu(gate) * up
            ys = _grouped(h, wd, group_sizes)
        ys = ys * flat_gate[order][:, None].astype(ys.dtype)
        out = jnp.zeros_like(xt).at[tok_sorted].add(ys)
        if has_model:
            out = jax.lax.psum(out, "model")
        frac_tokens = jnp.mean(
            (jax.nn.one_hot(expert_idx, e).sum(1) > 0).astype(jnp.float32), 0)
        frac_probs = jnp.mean(probs, axis=0)
        aux = e * jnp.sum(frac_tokens * frac_probs)
        aux = jax.lax.pmean(aux, dp) if dp else aux
        if has_model:
            aux = jax.lax.pmean(aux, "model")  # replicate for out_specs
        return out.reshape(b, s, d), aux

    if ep_mode:
        w_in_specs = (P("model", None, None), P("model", None, None),
                      P("model", None, None))
    else:
        mdl = "model" if has_model else None
        w_in_specs = (P(None, None, mdl), P(None, None, mdl),
                      P(None, mdl, None))
    in_specs = (P(dpa, None, None), P()) + w_in_specs
    out_specs = (P(dpa, None, None), P())
    if hasattr(jax, "shard_map"):
        smap = functools.partial(jax.shard_map, check_vma=False)
    else:  # older jax: experimental namespace, check_rep spelling
        from jax.experimental.shard_map import shard_map as _sm
        smap = functools.partial(_sm, check_rep=False)
    out, aux = smap(local_moe, mesh=mesh, in_specs=in_specs,
                    out_specs=out_specs)(x, rw, wg, wu, wd)

    if "shared_gate" in p:
        hs = jax.nn.silu(qmatmul(x, p["shared_gate"])) \
            * qmatmul(x, p["shared_up"])
        hs = constraint(hs, "batch", None, "ff")
        out = out + qmatmul(hs, p["shared_down"])
    return out, aux
