"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp


def rope_angles(positions: jnp.ndarray, d_head: int,
                theta: float = 1e4) -> jnp.ndarray:
    """(.., S) int positions -> (.., S, d_head//2) angles."""
    half = d_head // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    return positions.astype(jnp.float32)[..., None] * inv_freq


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, dh); angles: (B, S, dh//2) -> rotated x."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[..., None, :].astype(jnp.float32)
    sin = jnp.sin(angles)[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * cos - x2f * sin,
                            x2f * cos + x1f * sin], axis=-1).astype(dt)


def mrope_angles(positions3: jnp.ndarray, d_head: int, theta: float,
                 sections: Sequence[int] | None = None) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    positions3: (B, S, 3) = (temporal, height, width) position ids.  The
    d_head//2 frequency slots are split into ``sections`` (t, h, w); each
    section rotates with its own position stream.  Text tokens carry equal
    (t, h, w) ids, which makes M-RoPE degenerate to standard RoPE for them.
    """
    half = d_head // 2
    if sections is None:
        # Qwen2-VL ratio (16, 24, 24)/64 generalized to any head size.
        hw = 3 * half // 8
        sections = (half - 2 * hw, hw, hw)
    assert sum(sections) == half, (sections, half)
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.asarray(sections), total_repeat_length=half)
    # gather per-frequency-slot positions: (B, S, half)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(sec_id[None, None, :],
                         positions3.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1)
    return pos * inv_freq
