from .common import QuantConfig, materialize, rms_norm
