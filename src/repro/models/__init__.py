from .common import (QuantConfig, materialize, matmul_backend,
                     prepare_params, qdense, qmatmul, rms_norm)
