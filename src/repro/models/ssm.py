"""Mamba-2 (SSD) block with chunked prefix-scan — TPU-friendly formulation.

Training/prefill uses the chunked SSD algorithm (intra-chunk attention-form
matmuls + inter-chunk ``lax.scan`` over chunk states) so the MXU does the
work; decode keeps a per-layer recurrent state of O(H*N*P) — this is what
makes the ``long_500k`` cells tractable for the hybrid/SSM archs.

Projections are split (x/z/B/C/dt) so each weight shards cleanly and is
individually BWQ-quantizable.  dt/A/D are vectors and stay unquantized
(DESIGN.md §5 arch-applicability).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import constraint
from .common import make_weight, qmatmul, rms_norm


def init_mamba2(key, d_model: int, n_state: int, qc, expand: int = 2,
                headdim: int = 64, conv_k: int = 4, stack: int = 0,
                dtype=jnp.float32) -> Dict:
    """``stack`` > 0 builds scan-stacked (stack, ...) leaves directly."""
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    ks = jax.random.split(key, 8)
    L = (stack,) if stack else ()
    return {
        "in_x": make_weight(ks[0], (*L, d_model, d_inner), qc, dtype=dtype),
        "in_z": make_weight(ks[1], (*L, d_model, d_inner), qc, dtype=dtype),
        "in_B": make_weight(ks[2], (*L, d_model, n_state), qc, dtype=dtype),
        "in_C": make_weight(ks[3], (*L, d_model, n_state), qc, dtype=dtype),
        "in_dt": make_weight(ks[4], (*L, d_model, n_heads), qc, dtype=dtype),
        "conv1d_w": jax.random.normal(ks[5], (*L, conv_k, d_inner), dtype) * 0.2,
        "conv1d_b": jnp.zeros((*L, d_inner), dtype),
        "a_log": jnp.zeros((*L, n_heads), dtype),    # A = -exp(a_log)
        "d_skip": jnp.ones((*L, n_heads), dtype),
        "dt_bias": jnp.zeros((*L, n_heads), dtype),
        "norm_scale": jnp.zeros((*L, d_inner), dtype),
        "out_proj": make_weight(ks[6], (*L, d_inner, d_model), qc, dtype=dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along seq. x: (B, L, C), w: (K, C).

    Returns (y, new_state) where state caches the trailing K-1 inputs.
    """
    k = w.shape[0]
    if state is None:
        hist = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        hist = jnp.concatenate([state, x], axis=1)
    y = sum(hist[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_state = hist[:, -(k - 1):, :] if k > 1 else None
    return jax.nn.silu(y + b), new_state


def _ssd_chunked(xh, dt, da, B, C, h0, chunk: int):
    """Chunked SSD scan.

    xh: (b, L, H, P)   inputs per head
    dt: (b, L, H)      discretization steps (post-softplus)
    da: (b, L, H)      log decay per step (negative)
    B, C: (b, L, N)    input/output projections (single group)
    h0: (b, H, N, P)   initial state
    Returns (y (b, L, H, P), h_final).
    """
    b, L, H, P = xh.shape
    N = B.shape[-1]
    nc = L // chunk
    xh = xh.reshape(b, nc, chunk, H, P)
    dtc = dt.reshape(b, nc, chunk, H)
    dac = da.reshape(b, nc, chunk, H)
    Bc = B.reshape(b, nc, chunk, N)
    Cc = C.reshape(b, nc, chunk, N)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(h, ins):
        """One chunk: intra (attention-form matmuls) + inter (carried state).

        Sequential scan keeps live memory at O(one chunk) — the 32k/500k
        prefill cells depend on this (checkpointed for the backward pass).
        """
        xh_c, dt_c, da_c, b_c, c_c = ins   # (b,Q,H,P),(b,Q,H),(b,Q,H),(b,Q,N)x2
        lcum = jnp.cumsum(da_c, axis=1)                   # (b,Q,H)
        xdt = xh_c * dt_c[..., None]                      # (b,Q,H,P)
        rel = lcum[:, :, None, :] - lcum[:, None, :, :]   # (b,Q,Q,H)
        att = jnp.where(tri[None, :, :, None], jnp.exp(rel), 0.0)
        cb = jnp.einsum("bqn,bsn->bqs", c_c, b_c)         # (b,Q,Q)
        y_intra = jnp.einsum("bqs,bqsh,bshp->bqhp", cb, att, xdt)
        y_inter = jnp.einsum("bqn,bhnp->bqhp", c_c, h) \
            * jnp.exp(lcum)[..., None]
        dec_out = jnp.exp(lcum[:, -1:, :] - lcum)         # (b,Q,H)
        s_chunk = jnp.einsum("bsn,bsh,bshp->bhnp", b_c, dec_out, xdt)
        h_new = h * jnp.exp(lcum[:, -1, :])[:, :, None, None] + s_chunk
        return h_new, y_intra + y_inter

    seq = tuple(jnp.moveaxis(a, 1, 0) for a in (xh, dtc, dac, Bc, Cc))
    h_fin, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0, seq)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, L, H, P)        # (b,nc,Q,H,P)
    return y, h_fin


def mamba2_forward(p: Dict, x: jnp.ndarray, *, n_state: int,
                   headdim: int = 64, chunk: int = 128,
                   state: Optional[Dict] = None
                   ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x: (B, L, D).  With ``state`` (decode), L is typically 1."""
    b, L, d = x.shape
    chunk = min(chunk, L)
    xi = qmatmul(x, p["in_x"])
    z = qmatmul(x, p["in_z"])
    Bp = qmatmul(x, p["in_B"])
    Cp = qmatmul(x, p["in_C"])
    dt = jax.nn.softplus(qmatmul(x, p["in_dt"]) + p["dt_bias"])   # (B,L,H)
    h = dt.shape[-1]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = (dt.astype(jnp.float32) * a)                     # (B,L,H) log decay

    conv_state = state["conv"] if state is not None else None
    xi, new_conv = _causal_conv(xi, p["conv1d_w"], p["conv1d_b"], conv_state)
    xi = constraint(xi, "batch", None, "ff")
    xh = xi.reshape(b, L, h, headdim)

    h0 = state["ssm"] if state is not None else \
        jnp.zeros((b, h, n_state, headdim), jnp.float32)
    if L % chunk == 0 and L > 1:      # training AND chunked prefill
        y, h_fin = _ssd_chunked(xh.astype(jnp.float32),
                                dt.astype(jnp.float32), da,
                                Bp.astype(jnp.float32),
                                Cp.astype(jnp.float32), h0, chunk)
    else:

        def step(hc, ins):
            xh_t, dt_t, da_t, b_t, c_t = ins
            hc = hc * jnp.exp(da_t)[:, :, None, None] + \
                jnp.einsum("bn,bh,bhp->bhnp", b_t, dt_t, xh_t)
            y_t = jnp.einsum("bn,bhnp->bhp", c_t, hc)
            return hc, y_t

        seq = (jnp.moveaxis(xh.astype(jnp.float32), 1, 0),
               jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
               jnp.moveaxis(da, 1, 0),
               jnp.moveaxis(Bp.astype(jnp.float32), 1, 0),
               jnp.moveaxis(Cp.astype(jnp.float32), 1, 0))
        h_fin, ys = jax.lax.scan(step, h0, seq)
        y = jnp.moveaxis(ys, 0, 1)                        # (B,L,H,P)

    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(b, L, h * headdim).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    out = qmatmul(y, p["out_proj"])
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv, "ssm": h_fin}
    return out, new_state


def mamba2_init_state(batch: int, d_model: int, n_state: int,
                      expand: int = 2, headdim: int = 64, conv_k: int = 4,
                      dtype=jnp.float32) -> Dict:
    d_inner = expand * d_model
    h = d_inner // headdim
    return {
        "conv": jnp.zeros((batch, conv_k - 1, d_inner), dtype),
        "ssm": jnp.zeros((batch, h, n_state, headdim), jnp.float32),
    }
