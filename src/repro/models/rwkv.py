"""RWKV-6 "Finch" block: data-dependent per-channel decay linear attention.

Time-mix uses the GLA-style chunked form (log-space cumulative decays,
intra-chunk masked matmul + inter-chunk state scan) so prefill/training is
matmul-bound; decode carries an O(H * dk * dv) state per layer.  The
data-dependent decay ``w_t`` is produced by the paper's LoRA-style map
(w0 + tanh(x A) B).  Decay/bonus vectors are excluded from BWQ
(DESIGN.md §5); all Dense projections are quantizable.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import constraint
from .common import make_weight, qmatmul, rms_norm


def init_rwkv6(key, d_model: int, n_heads: int, qc, lora_r: int = 64,
               stack: int = 0, d_ff: int = 0, dtype=jnp.float32) -> Dict:
    """``stack`` > 0 builds scan-stacked (stack, ...) leaves directly
    (QuantizedTensor keeps its bit axis first either way)."""
    ks = jax.random.split(key, 10)
    dh = d_model // n_heads
    d_ff = d_ff or 7 * d_model // 2
    L = (stack,) if stack else ()
    return {
        # time mix
        "wr": make_weight(ks[0], (*L, d_model, d_model), qc, dtype=dtype),
        "wk": make_weight(ks[1], (*L, d_model, d_model), qc, dtype=dtype),
        "wv": make_weight(ks[2], (*L, d_model, d_model), qc, dtype=dtype),
        "wg": make_weight(ks[3], (*L, d_model, d_model), qc, dtype=dtype),
        "wo_t": make_weight(ks[4], (*L, d_model, d_model), qc, dtype=dtype),
        "decay_w0": jnp.full((*L, d_model), -6.0, dtype),
        "decay_a": jax.random.normal(ks[5], (*L, d_model, lora_r), dtype) * 0.02,
        "decay_b": jax.random.normal(ks[6], (*L, lora_r, d_model), dtype) * 0.02,
        "bonus_u": jnp.zeros((*L, n_heads, dh), dtype),
        "mix_r": jnp.full((*L, d_model), 0.5, dtype),
        "mix_k": jnp.full((*L, d_model), 0.5, dtype),
        "mix_v": jnp.full((*L, d_model), 0.5, dtype),
        "mix_w": jnp.full((*L, d_model), 0.5, dtype),
        "ln_x_scale": jnp.ones((*L, d_model), dtype),
        # channel mix
        "cm_wr": make_weight(ks[7], (*L, d_model, d_model), qc, dtype=dtype),
        "cm_wk": make_weight(ks[8], (*L, d_model, d_ff), qc, dtype=dtype),
        "cm_wv": make_weight(ks[9], (*L, d_ff, d_model), qc, dtype=dtype),
        "cm_mix_r": jnp.full((*L, d_model), 0.5, dtype),
        "cm_mix_k": jnp.full((*L, d_model), 0.5, dtype),
        "ln_t": jnp.zeros((*L, d_model), dtype),
        "ln_c": jnp.zeros((*L, d_model), dtype),
    }


def _token_shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]):
    """shifted[t] = x[t-1]; ``prev`` carries the last token across calls."""
    if prev is None:
        shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        shifted = jnp.concatenate([prev[:, None, :], x[:, :-1]], axis=1)
    return shifted, x[:, -1, :]


def _wkv_chunked(r, k, v, logw, u, s0, chunk: int):
    """Chunked linear attention with per-channel decay.

    r,k: (b, L, H, K); v: (b, L, H, V); logw: (b, L, H, K) (negative);
    u: (H, K) bonus for the diagonal; s0: (b, H, K, V).
    o_t = (u*k_t . r_t) v_t + r_t . S_{t-1};  S_t = w_t*S_{t-1} + k_t v_t^T
    (decay applied with the *current* token's w).
    """
    b, L, H, K = r.shape
    V = v.shape[-1]
    nc = L // chunk
    rs = r.reshape(b, nc, chunk, H, K)
    ks_ = k.reshape(b, nc, chunk, H, K)
    vs = v.reshape(b, nc, chunk, H, V)
    lw = logw.reshape(b, nc, chunk, H, K)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # strictly lower

    def chunk_step(s, ins):
        """One chunk, O(one chunk) live memory (sequential scan, remat'd).

        Intra-chunk A[q,s] = sum_k r_qk k_sk exp(dprev_q,k - dcum_s,k) in
        factored matmul form with a per-channel midpoint offset so neither
        factor overflows f32 (per-step logw clamped >= -4 upstream; with
        chunk<=32 the worst exponent is ~17*4 < 88).
        """
        r_c, k_c, v_c, lw_c = ins            # (b,Q,H,K) x3, (b,Q,H,V)
        dcum = jnp.cumsum(lw_c, axis=1)      # (b,Q,H,K)
        dprev = dcum - lw_c
        mid = dcum[:, chunk // 2: chunk // 2 + 1]
        qk = jnp.einsum("bqhk,bshk->bhqs",
                        r_c * jnp.exp(dprev - mid),
                        k_c * jnp.exp(mid - dcum))
        qk = jnp.where(tri[None, None], qk, 0.0)
        diag = jnp.einsum("bqhk,hk,bqhk->bhq", r_c, jnp.exp(u), k_c)
        o_intra = jnp.einsum("bhqs,bshv->bqhv", qk, v_c) + \
            jnp.einsum("bhq,bqhv->bqhv", diag, v_c)
        o_inter = jnp.einsum("bqhk,bhkv->bqhv", r_c * jnp.exp(dprev), s)
        dec_last = dcum[:, -1:]
        s_chunk = jnp.einsum("bshk,bshv->bhkv",
                             k_c * jnp.exp(dec_last - dcum), v_c)
        s_new = s * jnp.exp(dcum[:, -1])[..., None] + s_chunk
        return s_new, o_intra + o_inter

    seq = tuple(jnp.moveaxis(a, 1, 0) for a in (rs, ks_, vs, lw))
    s_fin, os_ = jax.lax.scan(jax.checkpoint(chunk_step), s0, seq)
    o = jnp.moveaxis(os_, 0, 1).reshape(b, L, H, V)
    return o, s_fin


def rwkv6_forward(p: Dict, h: jnp.ndarray, *, n_heads: int,
                  chunk: int = 32, state: Optional[Dict] = None
                  ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full RWKV6 layer: h = h + TimeMix(LN(h)); h = h + ChannelMix(LN(h))."""
    b, L, d = h.shape
    chunk = min(chunk, L)
    dh = d // n_heads
    x = rms_norm(h, p["ln_t"])
    prev_t = state["shift_t"] if state is not None else None
    shifted, last_t = _token_shift(x, prev_t)

    def mix(mu):
        return x + (shifted - x) * mu

    r = qmatmul(mix(p["mix_r"]), p["wr"]).reshape(b, L, n_heads, dh)
    k = qmatmul(mix(p["mix_k"]), p["wk"]).reshape(b, L, n_heads, dh)
    v = qmatmul(mix(p["mix_v"]), p["wv"]).reshape(b, L, n_heads, dh)
    g = jax.nn.silu(qmatmul(mix(p["mix_w"]), p["wg"]))
    r = constraint(r, "batch", None, "heads", None)

    xw = mix(p["mix_w"])
    logw = -jnp.exp(p["decay_w0"] +
                    jnp.tanh(xw @ p["decay_a"]) @ p["decay_b"])
    logw = jnp.maximum(logw, -4.0)  # decay floor; see _wkv_chunked overflow note
    logw = logw.reshape(b, L, n_heads, dh).astype(jnp.float32)

    s0 = state["wkv"] if state is not None else \
        jnp.zeros((b, n_heads, dh, dh), jnp.float32)

    if L % chunk == 0 and L > 1:      # training AND chunked prefill
        o, s_fin = _wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                                v.astype(jnp.float32), logw,
                                p["bonus_u"].astype(jnp.float32), s0, chunk)
    else:
        def step(s, ins):
            r_t, k_t, v_t, lw_t = ins
            o_t = jnp.einsum("bhk,bhkv->bhv", r_t, s) + \
                jnp.einsum("bhk,hk,bhk,bhv->bhv", r_t,
                           jnp.exp(p["bonus_u"].astype(jnp.float32)), k_t, v_t)
            s = s * jnp.exp(lw_t)[..., None] + \
                jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
            return s, o_t

        seq = tuple(jnp.moveaxis(t, 1, 0) for t in
                    (r.astype(jnp.float32), k.astype(jnp.float32),
                     v.astype(jnp.float32), logw))
        s_fin, os_ = jax.lax.scan(step, s0, seq)
        o = jnp.moveaxis(os_, 0, 1)

    o = o.reshape(b, L, d).astype(x.dtype)
    o = rms_norm(o, p["ln_x_scale"] - 1.0) * g
    h = h + qmatmul(o, p["wo_t"])

    # channel mix (with its own token shift) on the updated residual stream
    xc = rms_norm(h, p["ln_c"])
    prev_c = state["shift_c"] if state is not None else None
    shifted_c, last_c = _token_shift(xc, prev_c)

    def mixc(mu):
        return xc + (shifted_c - xc) * mu

    rc = jax.nn.sigmoid(qmatmul(mixc(p["cm_mix_r"]), p["cm_wr"]))
    kc = jnp.square(jax.nn.relu(qmatmul(mixc(p["cm_mix_k"]), p["cm_wk"])))
    kc = constraint(kc, "batch", None, "ff")
    h = h + rc * qmatmul(kc, p["cm_wv"])

    new_state = None
    if state is not None:
        new_state = {"shift_t": last_t, "shift_c": last_c, "wkv": s_fin}
    return h, new_state


def rwkv6_init_state(batch: int, d_model: int, n_heads: int,
                     dtype=jnp.float32) -> Dict:
    dh = d_model // n_heads
    return {
        "shift_t": jnp.zeros((batch, d_model), dtype),
        "shift_c": jnp.zeros((batch, d_model), dtype),
        "wkv": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
    }
