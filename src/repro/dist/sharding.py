"""Logical-axis sharding rules over a context-managed mesh.

The engine maps *logical* axis names ("batch", "heads", "ff", ...) and
*parameter paths* (regex over ``jax.tree_util.keystr`` strings) onto mesh
axes, maxtext-style.  Everything degrades to replicated ``P()`` no-ops when
no mesh is active, so single-device tests and examples run unchanged.

Conventions (see DESIGN.md and tests/test_sharding_rules.py):
  * column-parallel projections (wq/wk/wv, FFN up/gate) put their output
    dim on 'model';
  * row-parallel projections (wo, FFN down) put their input dim on 'model';
  * big weights additionally get their free dim sharded on 'data'
    (ZeRO-3 FSDP), gated on a size threshold and the ``FSDP`` toggle;
  * MoE routers and quantization metadata (scale / mask / bitwidth LUTs)
    stay replicated;
  * the data-parallel ("batch") logical axis spans every data-ish mesh
    axis present, in ('pod', 'data') order.

Every emitted spec is passed through :func:`fit_spec`, which drops mesh
axes that are absent or do not divide the corresponding dim — so rules are
written for the *production* mesh and degrade per-tensor everywhere else.
"""
from __future__ import annotations

import contextlib
import math
import re
import threading
from typing import Any, List, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# --------------------------------------------------------------------------
# mesh context
# --------------------------------------------------------------------------

_STATE = threading.local()


def get_mesh():
    """The innermost active mesh, or None (single-device / replicated)."""
    stack = getattr(_STATE, "mesh_stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_mesh(mesh):
    """Activate ``mesh`` for sharding rules; ``use_mesh(None)`` is a no-op
    context (kept so launchers can write ``with use_mesh(maybe_mesh):``)."""
    stack = getattr(_STATE, "mesh_stack", None)
    if stack is None:
        stack = _STATE.mesh_stack = []
    stack.append(mesh)
    try:
        yield mesh
    finally:
        stack.pop()


# Data-ish mesh axes in their canonical (outer -> inner) order.
DATA_AXES: Tuple[str, ...] = ("pod", "data")

# ZeRO-3 toggle: big weights get their free dim sharded on 'data'.
# benchmarks/hillclimb.py flips "enabled" around lowering variants.
FSDP = {"enabled": True, "min_bytes": 1 << 20}


def batch_axes(mesh=None) -> Tuple[str, ...]:
    """The data-parallel mesh axes present in ``mesh`` (pod-major)."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in DATA_AXES if a in mesh.shape)


def _batch_entry(mesh):
    dp = batch_axes(mesh)
    if not dp:
        return None
    return dp[0] if len(dp) == 1 else tuple(dp)


# logical axis name -> mesh axes (resolved against the active mesh)
_LOGICAL = {
    "batch": _batch_entry,
    "data": lambda mesh: "data",
    "pod": lambda mesh: "pod",
    "model": lambda mesh: "model",
    "heads": lambda mesh: "model",
    "kv_heads": lambda mesh: "model",
    "ff": lambda mesh: "model",
    "expert": lambda mesh: "model",
    "vocab": lambda mesh: "model",
}


def spec(*logical: Optional[str]) -> P:
    """Logical axis names -> PartitionSpec against the active mesh.

    Unknown names and ``None`` map to replicated dims.  The result is NOT
    divisibility-fitted; pair with :func:`fit_spec` (``constraint`` does)."""
    mesh = get_mesh()
    if mesh is None:
        return P()
    entries = []
    for name in logical:
        fn = _LOGICAL.get(name) if name is not None else None
        entries.append(fn(mesh) if fn else None)
    return P(*entries)


def fit_spec(ps: P, shape: Sequence[int], mesh=None) -> P:
    """Fit ``ps`` to ``shape`` under ``mesh``: drop axes that are not in the
    mesh, already used by an earlier dim, or whose combined size does not
    divide the dim.  Always returns a spec of ``len(shape)`` entries."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None:
        return P(*([None] * len(shape)))
    used: set = set()
    out: List[Any] = []
    for i, dim in enumerate(shape):
        entry = ps[i] if i < len(ps) else None
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = [a for a in axes if a in mesh.shape and a not in used]
        size = math.prod(mesh.shape[a] for a in axes)
        if not axes or size == 0 or dim % size:
            out.append(None)
        else:
            used.update(axes)
            out.append(tuple(axes) if len(axes) > 1 else axes[0])
    return P(*out)


def constraint(x, *logical: Optional[str]):
    """``with_sharding_constraint`` by logical axis names; identity with no
    active mesh.  Trailing dims beyond ``logical`` stay replicated."""
    mesh = get_mesh()
    if mesh is None:
        return x
    ps = fit_spec(spec(*logical), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, ps))


# --------------------------------------------------------------------------
# parameter rules (path-regex keyed, maxtext logical_axis_rules style)
# --------------------------------------------------------------------------
#
# Each rule is (compiled path regex, kind).  First match wins.
#   'replicated'  -> rank-matched P(None, ...)  (excluded from FSDP too)
#   'meta'        -> P()  (quant scales / masks / bit-width LUTs)
#   'col'         -> trailing (K, N): N on 'model', FSDP candidate dim K
#   'row'         -> trailing (K, N): K on 'model', FSDP candidate dim N
# Leading (stack / bit-plane) dims are never sharded by parameter rules.

_RULES: Tuple[Tuple[re.Pattern, str], ...] = tuple(
    (re.compile(pat), kind) for pat, kind in [
        # MoE routers stay replicated + fp32 (DESIGN.md §5): every data
        # shard routes its own tokens, no weight gather on the hot path.
        (r"router", "replicated"),
        # Quantization metadata: per-layer/per-WB scales, bit masks and
        # bit-width LUTs are tiny; replicate them everywhere.
        (r"\.(scale|mask|bitwidth)$", "meta"),
        (r"\['(k|v)_scale'\]", "meta"),
        # Norms / biases / PACT clip values: small 1-D-ish leaves.
        (r"\['(ln[_a-z0-9]*|final_norm|enc_norm|shared_ln2?|"
         r"beta_[a-z]+|b[qkv]|alpha)'\]", "replicated"),
        # Column-parallel: output dim on 'model'.
        (r"\['(wq|wk|wv|w_gate|w_up|w_in|shared_gate|shared_up|"
         r"expert_gate|expert_up|conv_pw1|lm_head|vision_proj)'\]", "col"),
        # Row-parallel: input dim on 'model'.
        (r"\['(wo|w_down|w_out|shared_down|expert_down|conv_pw2)'\]", "row"),
        # Token embedding (vocab, d): vocab rows on 'model' (matches the
        # tied lm-head orientation), free dim FSDP-able.
        (r"\['embed'\]", "row"),
    ])


def _leaf_bytes(leaf) -> int:
    try:
        return int(math.prod(leaf.shape)) * jax.dtypes.canonicalize_dtype(
            leaf.dtype).itemsize
    except (AttributeError, TypeError):
        return 0


def _leaf_spec(path: str, leaf) -> P:
    """PartitionSpec for one parameter leaf, keyed by its keystr path.

    ``path`` is a ``jax.tree_util.keystr`` string such as
    ``"['layers']['attn']['wo'].w"``; ``leaf`` is an array or
    ShapeDtypeStruct.  Requires an active mesh (otherwise ``P()``)."""
    mesh = get_mesh()
    shape = tuple(getattr(leaf, "shape", ()))
    if mesh is None or len(shape) < 1:
        return P()
    kind = None
    for pat, k in _RULES:
        if pat.search(path):
            kind = k
            break
    if kind == "meta":
        return P()
    rank = len(shape)
    dims: List[Any] = [None] * rank
    if kind in ("col", "row") and rank >= 2:
        model_dim = rank - 1 if kind == "col" else rank - 2
        fsdp_dim = rank - 2 if kind == "col" else rank - 1
        dims[model_dim] = "model"
        if FSDP["enabled"] and "data" in mesh.shape \
                and _leaf_bytes(leaf) >= FSDP["min_bytes"]:
            dims[fsdp_dim] = "data"
    return fit_spec(P(*dims), shape, mesh)


def param_pspecs(params) -> Any:
    """Tree of PartitionSpecs mirroring ``params`` (works on any pytree,
    including TrainState — optimizer moments inherit their weight's rule
    because the weight's dict key appears in their path too)."""
    mesh = get_mesh()
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    if mesh is None:
        specs = [P() for _ in flat]
    else:
        specs = [_leaf_spec(jax.tree_util.keystr(path), leaf)
                 for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def shard_params_tree(params):
    """Constrain every leaf of ``params`` to its rule spec (identity with
    no active mesh).  Called once per step on the materialized tree."""
    mesh = get_mesh()
    if mesh is None:
        return params
    specs = param_pspecs(params)
    return jax.tree_util.tree_map(
        lambda x, ps: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, ps)),
        params, specs)


# --------------------------------------------------------------------------
# batch / cache rules
# --------------------------------------------------------------------------

def batch_pspecs(batch) -> Any:
    """Shard dim 0 (the global batch) of every leaf across the data axes."""
    mesh = get_mesh()

    def leaf(x):
        shape = tuple(getattr(x, "shape", ()))
        if mesh is None or not shape:
            return P()
        dims: List[Any] = [None] * len(shape)
        dims[0] = _batch_entry(mesh)
        return fit_spec(P(*dims), shape, mesh)

    return jax.tree_util.tree_map(leaf, batch)


def cache_pspecs(state, batch_size: int) -> Any:
    """Decode-state specs: the batch dim (identified by ``batch_size``; the
    leading dim is the stacked layer axis) shards on the data axes, and the
    KV-head dim of rank>=5 ``(L, B, T, KV, dh)`` cache leaves shards on
    'model' — fitted, so e.g. 2 KV heads on a 16-way model axis degrade to
    replicated instead of failing.

    Paged caches are recognized by path: pool leaves under ``'pages'``
    (stack, P, page, KV, ...) shard their *page* axis on the data axes (the
    paged analog of per-slot batch sharding — gathers/scatters through the
    block table reshard as needed) and keep the rank>=5 KV-head rule;
    ``'table'`` block tables are tiny int32 maps and stay replicated."""
    mesh = get_mesh()
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)

    def _keys(path) -> List[str]:
        return [k.key for k in path
                if isinstance(k, jax.tree_util.DictKey)]

    def leaf(path, x):
        shape = tuple(getattr(x, "shape", ()))
        if mesh is None or not shape:
            return P()
        dims: List[Any] = [None] * len(shape)
        keys = _keys(path)
        if keys and keys[-1] == "table":
            return P(*dims)
        if "pages" in keys:
            dims[1] = _batch_entry(mesh)
            if len(shape) >= 5:
                dims[-2] = "model"
            return fit_spec(P(*dims), shape, mesh)
        # rank>=4 leaves are stacked (L, B, ...): dim 0 is the layer axis,
        # so never batch-shard it even when n_layers == batch_size.
        start = 1 if len(shape) >= 4 else 0
        for i in range(start, len(shape)):
            if shape[i] == batch_size:
                dims[i] = _batch_entry(mesh)
                break
        if len(shape) >= 5 and dims[-2] is None:
            dims[-2] = "model"
        return fit_spec(P(*dims), shape, mesh)

    specs = [leaf(path, x) for path, x in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)
