"""Logical-axis sharding rules over a context-managed mesh.

The engine maps *logical* axis names ("batch", "heads", "ff", ...) and
*parameter paths* (regex over ``jax.tree_util.keystr`` strings) onto mesh
axes, maxtext-style.  Everything degrades to replicated ``P()`` no-ops when
no mesh is active, so single-device tests and examples run unchanged.

Conventions (see DESIGN.md and tests/test_sharding_rules.py):
  * column-parallel projections (wq/wk/wv, FFN up/gate) put their output
    dim on 'model';
  * row-parallel projections (wo, FFN down) put their input dim on 'model';
  * big weights additionally get their free dim sharded on 'data'
    (ZeRO-3 FSDP), gated on a size threshold and the ``FSDP`` toggle;
  * MoE routers and quantization metadata (scale / mask / bitwidth LUTs)
    stay replicated;
  * the data-parallel ("batch") logical axis spans every data-ish mesh
    axis present, in ('pod', 'data') order.

Every emitted spec is passed through :func:`fit_spec`, which drops mesh
axes that are absent or already consumed — so rules are written for the
*production* mesh and degrade per-tensor everywhere else.  Axes that
exist but do not divide the dim are handled by **padded sharding**
(``PADDED``): the axis is kept, a :class:`SpecPad` event is recorded, and
the *placement boundary* (``pad_leaf`` before ``device_put``) zero-pads
the dim to the next multiple of the mesh-axis product; the consumer
masks by slicing back to the true shape in-graph (``unpad_leaf``).  Only
boundaries pad — in-graph ``with_sharding_constraint`` sites keep the
legacy drop rule (``pad=False``) because GSPMD silently *replicates*
uneven constraint specs on this jax, which would claim sharding it does
not deliver.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import re
import threading
import warnings
from typing import Any, List, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# --------------------------------------------------------------------------
# mesh context
# --------------------------------------------------------------------------

_STATE = threading.local()


def get_mesh():
    """The innermost active mesh, or None (single-device / replicated)."""
    stack = getattr(_STATE, "mesh_stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_mesh(mesh):
    """Activate ``mesh`` for sharding rules; ``use_mesh(None)`` is a no-op
    context (kept so launchers can write ``with use_mesh(maybe_mesh):``)."""
    stack = getattr(_STATE, "mesh_stack", None)
    if stack is None:
        stack = _STATE.mesh_stack = []
    stack.append(mesh)
    try:
        yield mesh
    finally:
        stack.pop()


# Data-ish mesh axes in their canonical (outer -> inner) order.
DATA_AXES: Tuple[str, ...] = ("pod", "data")

# ZeRO-3 toggle: big weights get their free dim sharded on 'data'.
# benchmarks/hillclimb.py flips "enabled" around lowering variants.
FSDP = {"enabled": True, "min_bytes": 1 << 20}

# Padded-sharding toggle: a mesh axis that does not divide a dim keeps
# the dim sharded via ceil-division padding instead of being dropped
# (vocab / kv-head dims no longer waste the whole model axis).  Callers
# can override per-call with ``fit_spec(..., pad=...)``.
PADDED = {"enabled": True}


def batch_axes(mesh=None) -> Tuple[str, ...]:
    """The data-parallel mesh axes present in ``mesh`` (pod-major)."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in DATA_AXES if a in mesh.shape)


def _batch_entry(mesh):
    dp = batch_axes(mesh)
    if not dp:
        return None
    return dp[0] if len(dp) == 1 else tuple(dp)


# logical axis name -> mesh axes (resolved against the active mesh)
_LOGICAL = {
    "batch": _batch_entry,
    "data": lambda mesh: "data",
    "pod": lambda mesh: "pod",
    "model": lambda mesh: "model",
    "heads": lambda mesh: "model",
    "kv_heads": lambda mesh: "model",
    "ff": lambda mesh: "model",
    "expert": lambda mesh: "model",
    "vocab": lambda mesh: "model",
}


class ShardingDropWarning(UserWarning):
    """A requested mesh axis did not divide its dim and was dropped."""


@dataclasses.dataclass(frozen=True)
class SpecDrop:
    """One mesh axis silently removed from a requested PartitionSpec.

    ``reason`` is ``'absent'`` (axis not in the mesh), ``'used'`` (axis
    already consumed by an earlier dim) or ``'indivisible'`` (the axis
    group's combined size does not divide the dim AND padding was
    disabled for the call — with :data:`PADDED` on, indivisible dims
    record a :class:`SpecPad` instead and stay sharded)."""
    label: str                 # leaf keystr, or '<unlabeled>'
    dim: int                   # which dim of the shape
    axis: str                  # the dropped mesh axis
    reason: str                # 'absent' | 'used' | 'indivisible'
    dim_size: int
    axis_size: int             # 0 when the axis is absent from the mesh

    def message(self) -> str:
        if self.reason == "indivisible":
            return (f"{self.label}: dim {self.dim} (size {self.dim_size}) "
                    f"is not divisible by mesh axis {self.axis!r} "
                    f"(size {self.axis_size}); axis dropped, dim serves "
                    f"replicated")
        if self.reason == "absent":
            return (f"{self.label}: dim {self.dim} requested mesh axis "
                    f"{self.axis!r}, which this mesh does not have")
        return (f"{self.label}: dim {self.dim} requested mesh axis "
                f"{self.axis!r}, already used by an earlier dim")


@dataclasses.dataclass(frozen=True)
class SpecPad:
    """One dim kept sharded by ceil-division padding.

    Recorded by :func:`fit_spec` when a requested mesh-axis group does
    not divide the dim but padded sharding is active: the placement
    boundary zero-pads ``dim_size`` up to ``padded_size`` (the next
    multiple of ``group_size``) and the consumer slices back."""
    label: str                 # leaf keystr, or '<unlabeled>'
    dim: int                   # which dim of the shape
    axes: Tuple[str, ...]      # the mesh axes kept on this dim
    dim_size: int
    padded_size: int
    group_size: int            # combined size of the kept axes

    def message(self) -> str:
        return (f"{self.label}: dim {self.dim} (size {self.dim_size}) "
                f"pads to {self.padded_size} for mesh axes "
                f"{'x'.join(self.axes)} (size {self.group_size}); "
                f"sharded via ceil-division, masked at the consumer")


@contextlib.contextmanager
def collect_spec_events():
    """Capture every :class:`SpecDrop` / :class:`SpecPad` recorded by
    :func:`fit_spec` in the dynamic extent (innermost collector wins;
    the sharding lint's event source)."""
    stack = getattr(_STATE, "spec_events", None)
    if stack is None:
        stack = _STATE.spec_events = []
    events: List[Any] = []          # SpecDrop | SpecPad
    stack.append(events)
    try:
        yield events
    finally:
        stack.pop()


_WARNED_DROPS: set = set()


def _record_drop(label: Optional[str], dim: int, axis: str, reason: str,
                 dim_size: int, axis_size: int) -> None:
    drop = SpecDrop(label=label or "<unlabeled>", dim=dim, axis=axis,
                    reason=reason, dim_size=dim_size, axis_size=axis_size)
    stack = getattr(_STATE, "spec_events", None)
    if stack:
        stack[-1].append(drop)
    if reason == "indivisible":
        key = (drop.label, dim, axis)
        if key not in _WARNED_DROPS:
            _WARNED_DROPS.add(key)
            warnings.warn(ShardingDropWarning(drop.message()), stacklevel=3)


def _record_pad(label: Optional[str], dim: int, axes: Tuple[str, ...],
                dim_size: int, padded_size: int, group_size: int) -> None:
    stack = getattr(_STATE, "spec_events", None)
    if stack:
        stack[-1].append(SpecPad(label=label or "<unlabeled>", dim=dim,
                                 axes=axes, dim_size=dim_size,
                                 padded_size=padded_size,
                                 group_size=group_size))


def spec(*logical: Optional[str]) -> P:
    """Logical axis names -> PartitionSpec against the active mesh.

    Unknown names and ``None`` map to replicated dims.  The result is NOT
    divisibility-fitted; pair with :func:`fit_spec` (``constraint`` does)."""
    mesh = get_mesh()
    if mesh is None:
        return P()
    entries = []
    for name in logical:
        fn = _LOGICAL.get(name) if name is not None else None
        entries.append(fn(mesh) if fn else None)
    return P(*entries)


def fit_spec(ps: P, shape: Sequence[int], mesh=None,
             label: Optional[str] = None, pad: Optional[bool] = None) -> P:
    """Fit ``ps`` to ``shape`` under ``mesh``: drop axes that are not in
    the mesh or already used by an earlier dim.  Always returns a spec of
    ``len(shape)`` entries.

    An axis group whose combined size does not divide the dim is kept
    via **ceil-division padded sharding** when ``pad`` is true (default:
    the :data:`PADDED` toggle) — the returned spec then describes the
    *padded* layout and placement must go through :func:`pad_leaf` /
    :func:`unpad_leaf`.  With ``pad=False`` the legacy rule applies: the
    axes are dropped, recorded as :class:`SpecDrop` events (to the
    active :func:`collect_spec_events` collector, if any), and an
    *indivisible* drop warns once per (label, dim, axis) with
    :class:`ShardingDropWarning`.  ``label`` names the tensor in those
    diagnostics (callers with tree paths pass the leaf keystr)."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None:
        return P(*([None] * len(shape)))
    do_pad = PADDED["enabled"] if pad is None else pad
    used: set = set()
    out: List[Any] = []
    for i, dim in enumerate(shape):
        entry = ps[i] if i < len(ps) else None
        if entry is None:
            out.append(None)
            continue
        axes = []
        for a in (entry if isinstance(entry, tuple) else (entry,)):
            if a not in mesh.shape:
                _record_drop(label, i, a, "absent", dim, 0)
            elif a in used:
                _record_drop(label, i, a, "used", dim, mesh.shape[a])
            else:
                axes.append(a)
        size = math.prod(mesh.shape[a] for a in axes)
        if axes and size > 1 and dim % size and do_pad:
            # keep sharded: the boundary zero-pads dim -> next multiple
            _record_pad(label, i, tuple(axes), dim,
                        -(-dim // size) * size, size)
            used.update(axes)
            out.append(tuple(axes) if len(axes) > 1 else axes[0])
        elif not axes or size == 0 or dim % size:
            for a in axes:
                _record_drop(label, i, a, "indivisible", dim, mesh.shape[a])
            out.append(None)
        else:
            used.update(axes)
            out.append(tuple(axes) if len(axes) > 1 else axes[0])
    return P(*out)


# --------------------------------------------------------------------------
# padded placement helpers
# --------------------------------------------------------------------------

def _group_size(entry, mesh) -> int:
    axes = entry if isinstance(entry, tuple) else (entry,)
    return math.prod(mesh.shape[a] for a in axes if a in mesh.shape)


def padded_shape(ps: P, shape: Sequence[int], mesh=None) -> Tuple[int, ...]:
    """The ceil-division padded shape ``ps`` implies for ``shape``:
    every sharded dim rounds up to the next multiple of its mesh-axis
    group size (identical to ``shape`` when everything divides)."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None:
        return tuple(shape)
    out = []
    for i, dim in enumerate(shape):
        entry = ps[i] if i < len(ps) else None
        if entry is None:
            out.append(dim)
            continue
        size = _group_size(entry, mesh)
        out.append(-(-dim // size) * size if size > 1 else dim)
    return tuple(out)


def pad_leaf(x, ps: P, mesh=None):
    """Zero-pad ``x`` to :func:`padded_shape` so an uneven spec becomes
    placeable with ``device_put`` (identity when nothing pads)."""
    import numpy as np
    shape = tuple(x.shape)
    target = padded_shape(ps, shape, mesh)
    if target == shape:
        return x
    widths = [(0, t - s) for s, t in zip(shape, target)]
    if isinstance(x, np.ndarray):
        return np.pad(x, widths)
    return jax.numpy.pad(x, widths)


def unpad_leaf(x, true_shape: Sequence[int]):
    """Slice a padded leaf back to its true shape (in-graph safe: the
    mask-at-the-consumer side of padded sharding).  Identity when the
    shapes already match."""
    shape = tuple(true_shape)
    if tuple(x.shape) == shape:
        return x
    return x[tuple(slice(0, s) for s in shape)]


def constraint(x, *logical: Optional[str]):
    """``with_sharding_constraint`` by logical axis names; identity with no
    active mesh.  Trailing dims beyond ``logical`` stay replicated.

    Always fits with ``pad=False``: an in-graph constraint cannot pad
    its operand, and GSPMD silently replicates uneven constraint specs
    on this jax — dropping the axis is the honest equivalent."""
    mesh = get_mesh()
    if mesh is None:
        return x
    ps = fit_spec(spec(*logical), x.shape, mesh, pad=False)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, ps))


# --------------------------------------------------------------------------
# parameter rules (path-regex keyed, maxtext logical_axis_rules style)
# --------------------------------------------------------------------------
#
# Each rule is (compiled path regex, kind).  First match wins.
#   'replicated'  -> rank-matched P(None, ...)  (excluded from FSDP too)
#   'meta'        -> P()  (quant scales / masks / bit-width LUTs)
#   'col'         -> trailing (K, N): N on 'model', FSDP candidate dim K
#   'row'         -> trailing (K, N): K on 'model', FSDP candidate dim N
# Leading (stack / bit-plane) dims are never sharded by parameter rules.

_RULES: Tuple[Tuple[re.Pattern, str], ...] = tuple(
    (re.compile(pat), kind) for pat, kind in [
        # MoE routers stay replicated + fp32 (DESIGN.md §5): every data
        # shard routes its own tokens, no weight gather on the hot path.
        (r"router", "replicated"),
        # Quantization metadata: per-layer/per-WB scales, bit masks and
        # bit-width LUTs are tiny; replicate them everywhere.
        (r"\.(scale|mask|bitwidth)$", "meta"),
        (r"\['(k|v)_scale'\]", "meta"),
        # Norms / biases / PACT clip values: small 1-D-ish leaves.
        (r"\['(ln[_a-z0-9]*|final_norm|enc_norm|shared_ln2?|"
         r"beta_[a-z]+|b[qkv]|alpha)'\]", "replicated"),
        # Column-parallel: output dim on 'model'.
        (r"\['(wq|wk|wv|w_gate|w_up|w_in|shared_gate|shared_up|"
         r"expert_gate|expert_up|conv_pw1|lm_head|vision_proj)'\]", "col"),
        # Row-parallel: input dim on 'model'.
        (r"\['(wo|w_down|w_out|shared_down|expert_down|conv_pw2)'\]", "row"),
        # Token embedding (vocab, d): vocab rows on 'model' (matches the
        # tied lm-head orientation), free dim FSDP-able.
        (r"\['embed'\]", "row"),
    ])


def _leaf_bytes(leaf) -> int:
    try:
        return int(math.prod(leaf.shape)) * jax.dtypes.canonicalize_dtype(
            leaf.dtype).itemsize
    except (AttributeError, TypeError):
        return 0


def _leaf_spec(path: str, leaf, pad: Optional[bool] = None) -> P:
    """PartitionSpec for one parameter leaf, keyed by its keystr path.

    ``path`` is a ``jax.tree_util.keystr`` string such as
    ``"['layers']['attn']['wo'].w"``; ``leaf`` is an array or
    ShapeDtypeStruct.  Requires an active mesh (otherwise ``P()``)."""
    mesh = get_mesh()
    shape = tuple(getattr(leaf, "shape", ()))
    if mesh is None or len(shape) < 1:
        return P()
    kind = None
    for pat, k in _RULES:
        if pat.search(path):
            kind = k
            break
    if kind == "meta":
        return P()
    rank = len(shape)
    dims: List[Any] = [None] * rank
    if kind in ("col", "row") and rank >= 2:
        model_dim = rank - 1 if kind == "col" else rank - 2
        fsdp_dim = rank - 2 if kind == "col" else rank - 1
        dims[model_dim] = "model"
        if FSDP["enabled"] and "data" in mesh.shape \
                and _leaf_bytes(leaf) >= FSDP["min_bytes"]:
            dims[fsdp_dim] = "data"
    return fit_spec(P(*dims), shape, mesh, label=path, pad=pad)


def param_pspecs(params, pad: Optional[bool] = None) -> Any:
    """Tree of PartitionSpecs mirroring ``params`` (works on any pytree,
    including TrainState — optimizer moments inherit their weight's rule
    because the weight's dict key appears in their path too).

    ``pad`` selects padded sharding for indivisible dims (default: the
    :data:`PADDED` toggle); a padded spec must be placed through
    :func:`pad_leaf` and consumed through :func:`unpad_leaf`."""
    mesh = get_mesh()
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    if mesh is None:
        specs = [P() for _ in flat]
    else:
        specs = [_leaf_spec(jax.tree_util.keystr(path), leaf, pad=pad)
                 for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def shard_params_tree(params):
    """Constrain every leaf of ``params`` to its rule spec (identity with
    no active mesh).  Called once per step on the materialized tree."""
    mesh = get_mesh()
    if mesh is None:
        return params
    specs = param_pspecs(params, pad=False)   # in-graph wsc cannot pad
    return jax.tree_util.tree_map(
        lambda x, ps: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, ps)),
        params, specs)


# --------------------------------------------------------------------------
# batch / cache rules
# --------------------------------------------------------------------------

def batch_pspecs(batch) -> Any:
    """Shard dim 0 (the global batch) of every leaf across the data axes.

    Always fits with ``pad=False``: a batch tensor is placed as-is every
    tick — padding it would fabricate tokens — so an indivisible batch
    serves replicated like before."""
    mesh = get_mesh()
    flat, treedef = jax.tree_util.tree_flatten_with_path(batch)

    def leaf(path, x):
        shape = tuple(getattr(x, "shape", ()))
        if mesh is None or not shape:
            return P()
        dims: List[Any] = [None] * len(shape)
        dims[0] = _batch_entry(mesh)
        return fit_spec(P(*dims), shape, mesh,
                        label=jax.tree_util.keystr(path), pad=False)

    return jax.tree_util.tree_unflatten(
        treedef, [leaf(path, x) for path, x in flat])


def cache_pspecs(state, batch_size: int, pad: Optional[bool] = None) -> Any:
    """Decode-state specs: the batch dim (identified by ``batch_size``; the
    leading dim is the stacked layer axis) shards on the data axes, and the
    KV-head dim of rank>=5 ``(L, B, T, KV, dh)`` cache leaves shards on
    'model' — fitted per ``pad`` (default: the :data:`PADDED` toggle), so
    e.g. 2 KV heads on a 16-way model axis pad-shard under padded mode
    and degrade to replicated with ``pad=False`` (the live engine's
    choice: decode state round-trips through the donated step and cannot
    carry placement padding).

    Paged caches are recognized by path: pool leaves under ``'pages'``
    (stack, P, page, KV, ...) shard their *page* axis on the data axes (the
    paged analog of per-slot batch sharding — gathers/scatters through the
    block table reshard as needed) and keep the rank>=5 KV-head rule;
    ``'table'`` block tables are tiny int32 maps and stay replicated."""
    mesh = get_mesh()
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)

    def _keys(path) -> List[str]:
        return [k.key for k in path
                if isinstance(k, jax.tree_util.DictKey)]

    def leaf(path, x):
        shape = tuple(getattr(x, "shape", ()))
        if mesh is None or not shape:
            return P()
        dims: List[Any] = [None] * len(shape)
        keys = _keys(path)
        if keys and keys[-1] == "table":
            return P(*dims)
        label = jax.tree_util.keystr(path)
        if "pages" in keys:
            dims[1] = _batch_entry(mesh)
            if len(shape) >= 5:
                dims[-2] = "model"
            return fit_spec(P(*dims), shape, mesh, label=label, pad=pad)
        # rank>=4 leaves are stacked (L, B, ...): dim 0 is the layer axis,
        # so never batch-shard it even when n_layers == batch_size.
        start = 1 if len(shape) >= 4 else 0
        for i in range(start, len(shape)):
            if shape[i] == batch_size:
                dims[i] = _batch_entry(mesh)
                break
        if len(shape) >= 5 and dims[-2] is None:
            dims[-2] = "model"
        return fit_spec(P(*dims), shape, mesh, label=label, pad=pad)

    specs = [leaf(path, x) for path, x in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)
