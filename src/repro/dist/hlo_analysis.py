"""HLO-text collective analysis + roofline terms.

``collective_stats`` scans compiled HLO (``compiled.as_text()``) for
cross-device collectives and totals their payload bytes per op, dtype-aware.
Async pairs are counted once at completion: ``*-start`` lines are skipped
and ``*-done`` lines are folded into their base op (the done instruction
carries the output shape).

``roofline_terms`` turns per-device FLOP / HBM-byte / collective-byte
totals into seconds against the chip constants below; ``dominant_term``
names the binding one.  Consumed by launch/dryrun.py and
benchmarks/roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# Per-chip constants (TPU-v4-class: bf16 matmul peak, HBM2e, per-chip ICI).
PEAK_FLOPS = 275e12      # FLOP/s
HBM_BW = 1.2e12          # bytes/s
ICI_BW = 0.3e12          # bytes/s (all links combined)

_COLLECTIVES = frozenset({
    "all-reduce", "all-gather", "all-to-all", "ragged-all-to-all",
    "reduce-scatter", "collective-permute", "collective-broadcast",
})

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "c64": 8,
    "s64": 8, "u64": 8, "f64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
}

# "%name = <shapes> opcode(operands...)" — minimal match pulls the first
# call-looking token after '=' as the opcode, everything before it as the
# result shape (possibly a tuple for async ops).
_INSTR = re.compile(
    r"=\s*(?P<shape>.*?)\s(?P<op>[a-z][a-z0-9-]*)\(")
_ARRAY = re.compile(r"([a-z][a-z0-9]*)\[([\d,\s]*)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _ARRAY.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            d = d.strip()
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_op: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Count collectives and total their result-shape bytes per op."""
    counts: Dict[str, int] = {}
    bytes_by_op: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR.search(line)
        if not m:
            continue
        op = m.group("op")
        if op.endswith("-start"):
            continue                      # counted at the matching -done
        if op.endswith("-done"):
            op = op[:-len("-done")]
        if op not in _COLLECTIVES:
            continue
        counts[op] = counts.get(op, 0) + 1
        bytes_by_op[op] = bytes_by_op.get(op, 0) \
            + _shape_bytes(m.group("shape"))
    return CollectiveStats(counts=counts, bytes_by_op=bytes_by_op)


# "{0}: (2, {1}, may-alias)" entries inside the module header's
# input_output_alias={...} block: output index -> donated parameter.
_ALIAS_ENTRY = re.compile(
    r"\{(?P<out>[\d,\s]*)\}:\s*\((?P<param>\d+),\s*\{(?P<path>[^}]*)\}")


def input_output_aliases(hlo_text: str):
    """Parse the module-level ``input_output_alias`` map from HLO text.

    Returns ``[(output_index_tuple, param_number, param_index_tuple)]`` —
    the compiled record of buffer donation.  Empty when the module
    donates nothing (the deep-check signal behind the graph lint's
    ``missing-donation`` rule; ``Lowered.args_info`` is the cheap
    lowering-level view of the same fact)."""
    key = "input_output_alias={"
    i = hlo_text.find(key)
    if i < 0:
        return []
    # the block nests braces ({out}: (p, {path}, ...)) — scan balanced
    j = i + len(key)
    depth, k = 1, j
    while k < len(hlo_text) and depth:
        if hlo_text[k] == "{":
            depth += 1
        elif hlo_text[k] == "}":
            depth -= 1
        k += 1
    out = []
    for e in _ALIAS_ENTRY.finditer(hlo_text[j:k - 1]):
        oidx = tuple(int(t) for t in e.group("out").split(",") if t.strip())
        pidx = tuple(int(t) for t in e.group("path").split(",")
                     if t.strip().isdigit())
        out.append((oidx, int(e.group("param")), pidx))
    return out


def shape_census(hlo_text: str, min_bytes: int = 0) -> Dict[str, int]:
    """Instruction-result footprint by dtype: ``{dtype: total_bytes}``.

    A compiled-HLO-level census of what the program holds: a packed
    serving program should be s8/u8-dominated — an f32 total on the order
    of the weight bytes is the compiled symptom of a dequant leak."""
    census: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR.search(line)
        if not m:
            continue
        for dtype, dims in _ARRAY.findall(m.group("shape")):
            if dtype not in _DTYPE_BYTES:
                continue
            n = _DTYPE_BYTES[dtype]
            for d in dims.split(","):
                d = d.strip()
                if d:
                    n *= int(d)
            if n >= min_bytes:
                census[dtype] = census.get(dtype, 0) + n
    return census


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float) -> Dict[str, float]:
    """Per-device totals -> time lower bounds per roofline resource."""
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": collective_bytes / ICI_BW,
    }


def dominant_term(terms: Dict[str, float]) -> str:
    return max(terms, key=lambda k: terms[k])
