"""Distribution layer: mesh-aware sharding rules + HLO collective analysis.

``repro.dist.sharding`` holds the logical-axis-rule engine (maxtext-style)
used by every model layer and the launchers; ``repro.dist.hlo_analysis``
parses compiled HLO for collective traffic and turns cost totals into
roofline terms.
"""
from . import hlo_analysis, sharding  # noqa: F401
