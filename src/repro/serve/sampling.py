"""Request-level serving types: sampling parameters, requests, results.

The serving surface is request-oriented (vLLM-style): callers submit
:class:`Request` objects carrying their own prompt tensors and
:class:`SamplingParams`; the scheduler streams them through a fixed-capacity
decode batch and hands back :class:`GenerationResult` per request.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode controls.

    ``temperature <= 0`` is greedy argmax (deterministic);  ``top_k > 0``
    restricts sampling to the k highest-probability tokens.  ``eos_id``
    retires the request early ('stop'); otherwise it runs to
    ``max_new_tokens`` ('length').  ``priority`` orders scheduler
    admission and preemption: higher values admit first and are parked
    last when an overcommitted page pool runs dry (ties break by arrival
    tick, then submission order)."""
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    eos_id: Optional[int] = None
    seed: int = 0
    priority: int = 0


@dataclasses.dataclass
class Request:
    """One generation request.

    ``inputs`` holds single-request prompt tensors with a leading batch dim
    of 1 (``tokens`` (1, P) always; plus ``vision_embeds`` for VLMs or
    ``frames`` for enc-dec).  ``arrival`` is the scheduler tick at which the
    request becomes visible — the hook for staggered-admission tests and
    trace-driven benchmarks."""
    uid: int
    inputs: Dict[str, jnp.ndarray]
    sampling: SamplingParams = SamplingParams()
    arrival: int = 0


@dataclasses.dataclass
class GenerationResult:
    uid: int
    tokens: List[int]
    finish_reason: str             # 'length' | 'stop'
    prompt_len: int
    admitted_tick: int             # tick the prompt entered the batch
    finished_tick: int


def sample_token(logits: jnp.ndarray, sp: SamplingParams, key) -> jnp.ndarray:
    """Token(s) from (V,) or batched (..., V) logits under ``sp`` (greedy
    when temperature<=0 or no key)."""
    if sp.temperature <= 0.0 or key is None:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    l = logits.astype(jnp.float32) / sp.temperature
    if sp.top_k > 0 and sp.top_k < l.shape[-1]:
        # rank-based mask so EXACTLY k candidates survive: a `l < kth`
        # threshold keeps every logit tied with the k-th value, silently
        # widening the filter past top_k; stable double-argsort breaks
        # ties by token id instead
        rank = jnp.argsort(jnp.argsort(-l, axis=-1), axis=-1)
        l = jnp.where(rank < sp.top_k, l, -jnp.inf)
    return jax.random.categorical(key, l).astype(jnp.int32)
