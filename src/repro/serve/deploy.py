"""Deployment parameter transform: QAT weights -> packed integer serving
weights (the TPU analogue of BWQ-H's compressed crossbar layout).

``to_serving_params`` converts every quantized leaf into a
:class:`ServingWeight` holding int8 (or nibble-packed int4) magnitudes plus
the per-WB scale/bit-width LUT.  ``materialize`` dequantizes in-graph, so
weight HBM traffic in the compiled program drops 4x/8x vs f32 — exactly the
memory-roofline lever BWQ's compression buys on a digital accelerator
(DESIGN.md §2; EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from ..core.bitrep import QuantizedTensor, compose_int, _levels
from ..core.blocking import BlockingSpec, expand_block_map, pad_to_blocks
from ..core.fakequant import FakeQuantTensor
from ..core.quantize import pack_int4, unpack_int4


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ServingWeight:
    """Packed integer weight + per-WB dequant metadata."""
    w_int: jnp.ndarray       # (..., Kp, Np) int8  or (..., Kp//2, Np) uint8
    scale: jnp.ndarray       # (..., GR, GC) f32 per-WB effective scale
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    spec: BlockingSpec = dataclasses.field(metadata=dict(static=True))
    bits: int = dataclasses.field(default=8, metadata=dict(static=True))


def _quantize_leaf(w, scale, bitwidth, spec, n_bits, bits) -> ServingWeight:
    """Shared packing math for both QAT representations."""
    shape = tuple(w.shape)
    wp = pad_to_blocks(w, spec)
    s = scale[..., None, None] if scale.ndim else scale
    levels = _levels(n_bits)
    q = jnp.round(jnp.abs(wp) / s * levels)
    cap = expand_block_map(2.0 ** bitwidth - 1.0, spec)
    q = jnp.clip(q, 0.0, cap)
    signed = jnp.where(wp < 0, -1.0, 1.0) * q
    # rescale blocks exceeding the container (bits-1 magnitude bits)
    shift = jnp.maximum(bitwidth - float(bits - 1), 0.0)
    factor = 2.0 ** shift
    f_full = expand_block_map(factor, spec)
    lim = 2 ** (bits - 1)
    wq = jnp.clip(jnp.round(signed / f_full), -lim, lim - 1).astype(jnp.int32)
    gscale = jnp.broadcast_to(
        (scale[..., None, None] if scale.ndim else scale) / levels,
        bitwidth.shape) * factor
    if bits == 8:
        w_int = wq.astype(jnp.int8)
    elif bits == 4:
        if wq.shape[-2] % 2:
            # nibble pairs pack along K: pad odd block-padded K with a zero
            # row (serving_compose trims back to ``shape``)
            pad = [(0, 0)] * wq.ndim
            pad[-2] = (0, 1)
            wq = jnp.pad(wq, pad)
        w_int = pack_int4(wq, axis=-2)
    else:
        raise ValueError(bits)
    return ServingWeight(w_int=w_int, scale=gscale.astype(jnp.float32),
                         shape=shape, spec=spec, bits=bits)


def to_serving_params(params: Any, bits: int = 8) -> Any:
    """Convert all quantized leaves to packed ServingWeight."""
    def conv(x):
        if isinstance(x, QuantizedTensor):
            from ..core.bitrep import compose
            return _quantize_leaf(compose(x), x.scale,
                                  jnp.sum(x.mask, axis=0), x.spec,
                                  x.n_bits, bits)
        if isinstance(x, FakeQuantTensor):
            return _quantize_leaf(x.w, x.scale, x.bitwidth, x.spec,
                                  x.n_bits, bits)
        return x
    return jax.tree_util.tree_map(
        conv, params,
        is_leaf=lambda x: isinstance(x, (QuantizedTensor, FakeQuantTensor)))


def serving_to_packed_layout(sw: ServingWeight):
    """Adapt a (2-D) ServingWeight leaf to the kernel-facing PackedLayout.

    Zero-copy: both sides share the wire format (see kernels/ops.py for the
    contract), so deployment packing feeds ``packed_matmul`` directly.  The
    per-WB scale already folds each block's power-of-two rescale factor, so
    blocks quantized to fewer bits dequantize exactly — BWQ's mixed
    precision reaches the kernel instead of being flattened to uniform
    int8.  Stacked leaves (L/E leading dims) are sliced by the layer scan
    before they get here; ``sw.shape`` then still carries the stacked true
    shape, so only the trailing (K, N) may be consulted.
    """
    from ..kernels.ops import PackedLayout
    return PackedLayout(w_int=sw.w_int, scale=sw.scale, bits=sw.bits,
                        wbr=sw.spec.wb_rows, wbc=sw.spec.wb_cols)


def default_deploy_bits(backend: str, deploy_bits: int) -> int:
    """CLI rule with one owner: packed execution backends need packed
    weights, so an unset ``--deploy-bits`` defaults to int8 for them."""
    return deploy_bits or (8 if backend != "dense" else 0)


def weight_stream_bytes(params) -> int:
    """HBM bytes of weight state one full forward/decode step streams.

    ServingWeight leaves count their packed payload (w_int + per-WB
    scales); QAT representations and plain arrays count every array leaf
    as stored — which is exactly what the dense backend reads per step.
    """
    total = 0
    for leaf in jax.tree_util.tree_leaves(params):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
    return total


def serving_compose(sw: ServingWeight, dtype=jnp.bfloat16) -> jnp.ndarray:
    """In-graph dequantization (int8/int4 stream -> bf16 weights)."""
    if sw.bits == 8:
        wq = sw.w_int.astype(jnp.float32)
    else:
        wq = unpack_int4(sw.w_int, axis=-2).astype(jnp.float32)
    s_full = expand_block_map(sw.scale, sw.spec)
    # odd block-padded K packs one zero row; trim back to the scale map
    wq = wq[..., :s_full.shape[-2], :]
    w = wq * s_full
    k, n = sw.shape[-2], sw.shape[-1]
    return w[..., :k, :n].astype(dtype)
