"""Deployment parameter transform: QAT weights -> compressed serving
weights (the TPU analogue of BWQ-H's compressed crossbar layout).

``to_serving_params`` converts every quantized leaf into one of two wire
formats sharing the exact same integer grid (``_integer_grid``):

* ``layout="packed"`` — :class:`ServingWeight`: int8 (or nibble-packed
  int4) magnitudes plus the per-WB scale/bit-width LUT, consumed by the
  ``packed_matmul`` kernel;
* ``layout="bitplane"`` — :class:`BitplaneServingWeight`: the paper's
  precision-aware mapping.  Each weight block is stored as 1-bit planes
  (8 rows/byte) plus a packed sign plane, a binary (bit, block) mask LUT
  and the per-WB effective scale; a block quantized to b bits occupies
  exactly ``min(b, bits)`` live planes, so streamed bytes track the BWQ-A
  precision assignment (paper Fig. 5c OU mapping).  All tensors keep
  layer-stack dims leading, so stacked leaves ride the transformer layer
  scan and are sliced one layer at a time.

Because both layouts quantize through the same math, ``dense`` execution
composes bit-identical weights from either — the backend-parity matrix in
tests/test_backend_parity.py holds across representations, not just
kernels.  ``weight_stream_bytes`` accounts HBM traffic per step; for the
bitplane layout it counts true per-block plane occupancy (a 2-bit block
streams 2 planes, not a dtype-wide word).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bitrep import QuantizedTensor, _levels
from ..core.blocking import BlockingSpec, expand_block_map, pad_to_blocks
from ..core.fakequant import FakeQuantTensor
from ..core.quantize import pack_int4, unpack_int4

SERVING_LAYOUTS = ("packed", "bitplane")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ServingWeight:
    """Packed integer weight + per-WB dequant metadata."""
    w_int: jnp.ndarray       # (..., Kp, Np) int8  or (..., Kp//2, Np) uint8
    scale: jnp.ndarray       # (..., GR, GC) f32 per-WB effective scale
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    spec: BlockingSpec = dataclasses.field(metadata=dict(static=True))
    bits: int = dataclasses.field(default=8, metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BitplaneServingWeight:
    """Bit-plane-sliced weight: the paper's precision-aware OU mapping.

    Layer-stack dims lead every tensor (scan-sliceable, unlike the QAT
    ``QuantizedTensor`` whose bit axis leads).  ``Kp8`` is the WB-padded
    row count rounded up to a byte boundary — an odd block-padded K (the
    9x8 paper geometry) packs zero rows up to the next multiple of 8,
    mirroring the packed layout's odd-K nibble trick.  ``mask[b, g, h]``
    is 1 iff block (g, h) keeps plane ``b`` live; dequantization is
    ``(1 - 2*sign) * sum_b 2^b plane_b mask_b * scale`` with the per-WB
    effective ``scale`` pre-folding /(2^n - 1) and each block's
    power-of-two container rescale."""
    planes: jnp.ndarray      # (..., bits, Kp8//8, Np) uint8 packed planes
    sign: jnp.ndarray        # (..., Kp8//8, Np) uint8 packed sign plane
    mask: jnp.ndarray        # (..., bits, GR, GC) f32 in {0., 1.}
    scale: jnp.ndarray       # (..., GR, GC) f32 per-WB effective scale
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    spec: BlockingSpec = dataclasses.field(metadata=dict(static=True))
    bits: int = dataclasses.field(default=8, metadata=dict(static=True))
    # Static identity label (tree path), set by the autotune calibration
    # pass: it survives the per-layer tree_map slicing of scan_or_loop, so
    # the qmatmul activation recorder can key captured statistics back to
    # the stacked deployed leaf.  Empty outside calibration.
    tag: str = dataclasses.field(default="", metadata=dict(static=True))


def _integer_grid(w, scale, bitwidth, spec, n_bits, bits):
    """Quantization math shared by both serving layouts.

    Returns ``(wq, gscale, shape)``: block-padded signed integers
    (..., Kp, Np) in [-2^(bits-1), 2^(bits-1)-1], the per-WB effective
    scale (..., GR, GC) with each block's power-of-two container rescale
    folded in, and the true (unpadded) shape."""
    shape = tuple(w.shape)
    wp = pad_to_blocks(w, spec)
    s = scale[..., None, None] if scale.ndim else scale
    levels = _levels(n_bits)
    q = jnp.round(jnp.abs(wp) / s * levels)
    cap = expand_block_map(2.0 ** bitwidth - 1.0, spec)
    q = jnp.clip(q, 0.0, cap)
    signed = jnp.where(wp < 0, -1.0, 1.0) * q
    # rescale blocks exceeding the container (bits-1 magnitude bits)
    shift = jnp.maximum(bitwidth - float(bits - 1), 0.0)
    factor = 2.0 ** shift
    f_full = expand_block_map(factor, spec)
    lim = 2 ** (bits - 1)
    wq = jnp.clip(jnp.round(signed / f_full), -lim, lim - 1).astype(jnp.int32)
    gscale = jnp.broadcast_to(
        (scale[..., None, None] if scale.ndim else scale) / levels,
        bitwidth.shape) * factor
    return wq, gscale.astype(jnp.float32), shape


def _pack_packed(wq, gscale, shape, spec, bits) -> ServingWeight:
    if bits == 8:
        w_int = wq.astype(jnp.int8)
    elif bits == 4:
        if wq.shape[-2] % 2:
            # nibble pairs pack along K: pad odd block-padded K with a zero
            # row (serving_compose trims back to ``shape``)
            pad = [(0, 0)] * wq.ndim
            pad[-2] = (0, 1)
            wq = jnp.pad(wq, pad)
        w_int = pack_int4(wq, axis=-2)
    else:
        raise ValueError(bits)
    return ServingWeight(w_int=w_int, scale=gscale, shape=shape, spec=spec,
                         bits=bits)


def _pack_bitplane(wq, gscale, bitwidth, shape, spec,
                   bits) -> BitplaneServingWeight:
    """Slice the shared integer grid into packed 1-bit planes.

    A block whose live bit-width is bw keeps ``min(bw, bits)`` planes:
    below the container every magnitude fits in bw bits; at/above it the
    container rescale leaves at most ``bits`` significant bits (the -2^(
    bits-1) clip endpoint lands exactly on plane ``bits-1``)."""
    from ..kernels.ref import pack_bits
    kp = wq.shape[-2]
    kp8 = -(-kp // 8) * 8
    if kp8 != kp:                    # odd block-padded K: zero byte-pad rows
        pad = [(0, 0)] * wq.ndim
        pad[-2] = (0, kp8 - kp)
        wq = jnp.pad(wq, pad)
    mag = jnp.abs(wq)
    planes = jnp.stack([((mag >> b) & 1).astype(jnp.uint8)
                        for b in range(bits)], axis=-3)
    planes_packed = pack_bits(planes)              # (..., bits, Kp8//8, Np)
    sign_packed = pack_bits((wq < 0).astype(jnp.uint8))
    live = jnp.minimum(bitwidth, float(bits))      # (..., GR, GC)
    plane_idx = jnp.arange(bits, dtype=live.dtype).reshape((bits, 1, 1))
    mask = (plane_idx < live[..., None, :, :]).astype(jnp.float32)
    return BitplaneServingWeight(planes=planes_packed, sign=sign_packed,
                                 mask=mask, scale=gscale, shape=shape,
                                 spec=spec, bits=bits)


def _quantize_leaf(w, scale, bitwidth, spec, n_bits, bits,
                   layout: str = "packed"):
    wq, gscale, shape = _integer_grid(w, scale, bitwidth, spec, n_bits, bits)
    if layout == "bitplane":
        return _pack_bitplane(wq, gscale, bitwidth, shape, spec, bits)
    return _pack_packed(wq, gscale, shape, spec, bits)


def _convert_leaf(x, bits: int, layout: str):
    if isinstance(x, QuantizedTensor):
        from ..core.bitrep import compose
        return _quantize_leaf(compose(x), x.scale,
                              jnp.sum(x.mask, axis=0), x.spec,
                              x.n_bits, bits, layout)
    if isinstance(x, FakeQuantTensor):
        return _quantize_leaf(x.w, x.scale, x.bitwidth, x.spec,
                              x.n_bits, bits, layout)
    return x


def _is_quant(x) -> bool:
    return isinstance(x, (QuantizedTensor, FakeQuantTensor))


def _serving_params_from_ckpt(path: str, bits: int, layout: str,
                              template: Any, stats: Any) -> Any:
    """Stream a checkpoint straight into serving form, leaf by leaf.

    One quantized leaf's f32 working set is resident at a time — the
    dense tree is never materialized on the host, which is what makes a
    fleet cold-start from a multi-GB checkpoint fit in serving-host RAM.
    ``template`` is the *abstract* QAT tree (``api.abstract_params()``)
    that carries the static structure (BlockingSpec, n_bits, ...) the
    checkpoint does not store.  TrainState checkpoints are recognized by
    their ``.params`` key prefix, so the optimizer state is never read."""
    from ..ckpt.checkpoint import CheckpointReader
    reader = CheckpointReader(path)
    try:
        keys = set(reader.keys())
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            template, is_leaf=_is_quant)
        prefix = ""
        probe = jax.tree_util.keystr(flat[0][0]) if flat else ""
        if not any(k.startswith(probe) for k in keys) \
                and any(k.startswith(".params") for k in keys):
            prefix = ".params"

        peak = in_flight = dense = 0
        out_leaves = []
        for p, leaf in flat:
            base = prefix + jax.tree_util.keystr(p)
            cflat, cdef = jax.tree_util.tree_flatten_with_path(leaf)
            arrays = []
            for cp, _ in cflat:
                arr = reader.read(base + jax.tree_util.keystr(cp))
                arrays.append(arr)
                in_flight += arr.nbytes
            peak = max(peak, in_flight)
            rebuilt = jax.tree_util.tree_unflatten(
                cdef, [jnp.asarray(a) for a in arrays])
            out_leaves.append(_convert_leaf(rebuilt, bits, layout))
            for arr in arrays:
                in_flight -= arr.nbytes
                dense += arr.nbytes
            del arrays, rebuilt
        if isinstance(stats, dict):
            stats.update(peak_host_bytes=peak, dense_tree_bytes=dense,
                         leaves=len(flat), source=path)
        return jax.tree_util.tree_unflatten(treedef, out_leaves)
    finally:
        reader.close()


def to_serving_params(params: Any, bits: int = 8, layout: str = "packed",
                      validate: bool = True, template: Any = None,
                      stats: Any = None) -> Any:
    """Convert all quantized leaves to the chosen serving wire format.

    ``params`` is either a live QAT tree or a **checkpoint directory
    path** — the latter streams shard-by-shard through
    :func:`_serving_params_from_ckpt` (requires ``template``, the
    abstract QAT tree) without ever materializing the dense f32 tree;
    ``stats`` (a dict, mutated in place) then reports
    ``peak_host_bytes`` vs ``dense_tree_bytes``.

    ``validate`` contract-checks the result (``analysis.contracts``) so a
    packing bug is caught at deploy time with a path-qualified diagnostic
    rather than as a parity failure deep in a kernel."""
    if layout not in SERVING_LAYOUTS:
        raise ValueError(f"unknown serving layout {layout!r}; "
                         f"choose from {SERVING_LAYOUTS}")

    if isinstance(params, str):
        if template is None:
            raise ValueError(
                "to_serving_params(checkpoint_path, ...) needs template= "
                "(the abstract QAT tree from api.abstract_params())")
        out = _serving_params_from_ckpt(params, bits, layout, template,
                                        stats)
    else:
        out = jax.tree_util.tree_map(
            lambda x: _convert_leaf(x, bits, layout), params,
            is_leaf=_is_quant)
    if validate:
        from ..analysis.contracts import validate_serving_tree
        bad = [f for f in validate_serving_tree(out)
               if f.severity == "error"]
        if bad:
            raise ValueError(
                "deployment produced a contract-violating tree:\n"
                + "\n".join(f.format() for f in bad[:8]))
    return out


def serving_to_packed_layout(sw: ServingWeight):
    """Adapt a (2-D) ServingWeight leaf to the kernel-facing PackedLayout.

    Zero-copy: both sides share the wire format (see kernels/ops.py for the
    contract), so deployment packing feeds ``packed_matmul`` directly.  The
    per-WB scale already folds each block's power-of-two rescale factor, so
    blocks quantized to fewer bits dequantize exactly — BWQ's mixed
    precision reaches the kernel instead of being flattened to uniform
    int8.  Stacked leaves (L/E leading dims) are sliced by the layer scan
    before they get here; ``sw.shape`` then still carries the stacked true
    shape, so only the trailing (K, N) may be consulted.
    """
    from ..kernels.ops import PackedLayout
    return PackedLayout(w_int=sw.w_int, scale=sw.scale, bits=sw.bits,
                        wbr=sw.spec.wb_rows, wbc=sw.spec.wb_cols)


def serving_to_bitplane_layout(sw: BitplaneServingWeight):
    """Adapt a (2-D) BitplaneServingWeight leaf to the kernel-facing
    BitplaneLayout.  Zero-copy, like :func:`serving_to_packed_layout`;
    the per-WB effective ``scale`` LUT rides along, selecting the
    kernel's pre-folded per-block dequant path.  Stacked leaves are
    sliced by the layer scan before they get here."""
    from ..kernels.ops import BitplaneLayout
    return BitplaneLayout(planes_packed=sw.planes, sign_packed=sw.sign,
                          mask=sw.mask, scale=sw.scale, n_bits=sw.bits,
                          wbr=sw.spec.wb_rows, wbc=sw.spec.wb_cols)


def default_deploy_bits(backend: str, deploy_bits: int) -> int:
    """CLI rule with one owner: packed execution backends need packed
    weights, so an unset ``--deploy-bits`` defaults to int8 for them."""
    return deploy_bits or (8 if backend != "dense" else 0)


def default_deploy_layout(backend: str) -> str:
    """The wire format a backend executes natively: ``bitplane`` streams
    plane-sliced weights, everything else the packed integer form."""
    return "bitplane" if backend == "bitplane" else "packed"


def bitplane_stream_bytes(sw: BitplaneServingWeight) -> int:
    """Streamed bytes for one pass over a bit-plane leaf, by occupancy.

    Each live (bit, block) mask entry streams one wbr x wbc 1-bit plane
    tile; a block with any live plane also streams its sign tile (fully
    masked blocks are skipped whole, like the OUs the memory controller
    never fetches).  The per-WB scale LUT streams as stored f32 and the
    binary mask LUT at one bit per entry.  Byte-boundary padding rows are
    not billed — they exist only for the packed wire format."""
    wbr, wbc = sw.spec.wb_rows, sw.spec.wb_cols
    mask = np.asarray(sw.mask)
    live_planes = int(mask.sum())
    live_blocks = int((mask.sum(axis=-3) > 0).sum())
    plane_bits = (live_planes + live_blocks) * wbr * wbc
    mask_bits = mask.size
    return int(-(-plane_bits // 8) + -(-mask_bits // 8)
               + int(sw.scale.nbytes))


def weight_stream_bytes(params) -> int:
    """HBM bytes of weight state one full forward/decode step streams.

    BitplaneServingWeight leaves count per-block plane occupancy
    (:func:`bitplane_stream_bytes`) — the first accounting where streamed
    bytes vary with the BWQ-A precision assignment; packed ServingWeight
    leaves count their packed payload (w_int + per-WB scales); QAT
    representations and plain arrays count every array leaf as stored —
    which is exactly what the dense backend reads per step.
    """
    total = 0
    leaves = jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, BitplaneServingWeight))
    for leaf in leaves:
        if isinstance(leaf, BitplaneServingWeight):
            total += bitplane_stream_bytes(leaf)
        elif hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
    return total


def serving_compose(sw: ServingWeight, dtype=jnp.bfloat16) -> jnp.ndarray:
    """In-graph dequantization (int8/int4 stream -> bf16 weights)."""
    if sw.bits == 8:
        wq = sw.w_int.astype(jnp.float32)
    else:
        wq = unpack_int4(sw.w_int, axis=-2).astype(jnp.float32)
    s_full = expand_block_map(sw.scale, sw.spec)
    # odd block-padded K packs one zero row; trim back to the scale map
    wq = wq[..., :s_full.shape[-2], :]
    w = wq * s_full
    k, n = sw.shape[-2], sw.shape[-1]
    return w[..., :k, :n].astype(dtype)


def repack_bitplane_leaf(sw: BitplaneServingWeight,
                         new_occ) -> BitplaneServingWeight:
    """Re-pack a bit-plane leaf to reduced per-block plane occupancies.

    ``new_occ`` is an (..., GR, GC) integer-valued array with
    ``0 <= new_occ <= current occupancy``.  A block dropping ``d`` planes
    re-rounds its magnitudes onto the coarser grid — ``q' = clip(round(
    q / 2^d), 0, 2^new_occ - 1)`` — and folds ``2^d`` into its effective
    scale entry, so the emitted leaf is a *valid* deployment: the mask is
    prefix-monotone over the new occupancies (BP2) and byte-pad rows stay
    zero (BP1).  Blocks with ``d == 0`` are reproduced bit-identically,
    so a full-budget allocation round-trips the deployed tree exactly.
    Host-side numpy: this runs in the offline autotune search, never on
    the serving hot path.
    """
    from ..kernels.ref import pack_bits, unpack_bits
    wbr, wbc = sw.spec.wb_rows, sw.spec.wb_cols
    mask = np.asarray(sw.mask, dtype=np.float64)    # (..., bits, GR, GC)
    occ = mask.sum(axis=-3)                         # (..., GR, GC)
    new_occ = np.asarray(new_occ, dtype=np.float64)
    if new_occ.shape != occ.shape:
        raise ValueError(f"new_occ shape {new_occ.shape} != grid {occ.shape}")
    if np.any(new_occ < 0) or np.any(new_occ > occ):
        raise ValueError("new occupancy must lie in [0, deployed occupancy]")
    bits = sw.bits
    gr, gc = mask.shape[-2], mask.shape[-1]
    kp, np_ = gr * wbr, gc * wbc
    planes = np.asarray(unpack_bits(sw.planes), dtype=np.float64)
    kp8 = planes.shape[-2]

    def _expand(block_map):                         # (..., GR, GC) -> (Kp, Np)
        return np.repeat(np.repeat(block_map, wbr, axis=-2), wbc, axis=-1)

    weights = (2.0 ** np.arange(bits)).reshape((bits, 1, 1))
    m_full = _expand(mask)                          # (..., bits, Kp, Np)
    mag = (planes[..., :kp, :] * m_full * weights).sum(axis=-3)
    drop = occ - new_occ
    q = np.round(mag / 2.0 ** _expand(drop))
    # round() can carry into plane new_occ (q near the old ceiling); clip
    # back onto the coarser grid so the prefix mask stays exact.
    q = np.minimum(q, 2.0 ** _expand(new_occ) - 1.0).astype(np.int64)
    new_planes = np.stack([((q >> b) & 1).astype(np.uint8)
                           for b in range(bits)], axis=-3)
    if kp8 != kp:                                   # restore byte-pad rows
        pad = [(0, 0)] * new_planes.ndim
        pad[-2] = (0, kp8 - kp)
        new_planes = np.pad(new_planes, pad)
    plane_idx = np.arange(bits, dtype=np.float64).reshape((bits, 1, 1))
    new_mask = (plane_idx < new_occ[..., None, :, :]).astype(np.float32)
    new_scale = (np.asarray(sw.scale, dtype=np.float64)
                 * 2.0 ** drop).astype(np.float32)
    return dataclasses.replace(
        sw, planes=pack_bits(jnp.asarray(new_planes)),
        mask=jnp.asarray(new_mask), scale=jnp.asarray(new_scale))


def bitplane_serving_compose(sw: BitplaneServingWeight,
                             dtype=jnp.bfloat16) -> jnp.ndarray:
    """In-graph dequantization of the bit-plane layout (dense backend).

    Elementwise identical to :func:`serving_compose` on the packed form
    of the same leaf: the plane sum reproduces each |wq| exactly (integer
    arithmetic below 2^bits is exact in f32) and the per-WB effective
    scale is the same LUT, so the two layouts are interchangeable under
    ``dense`` execution."""
    from ..kernels.ref import unpack_bits
    planes = unpack_bits(sw.planes)                # (..., bits, Kp8, Np)
    sign = 1.0 - 2.0 * unpack_bits(sw.sign)        # (..., Kp8, Np)
    m_full = expand_block_map(sw.mask, sw.spec)    # (..., bits, Kp, Np)
    kp = m_full.shape[-2]
    weights = (2.0 ** jnp.arange(sw.bits, dtype=jnp.float32)
               ).reshape((sw.bits, 1, 1))
    mag = jnp.sum(planes[..., :kp, :] * m_full * weights, axis=-3)
    w = sign[..., :kp, :] * (mag * expand_block_map(sw.scale, sw.spec))
    k, n = sw.shape[-2], sw.shape[-1]
    return w[..., :k, :n].astype(dtype)
