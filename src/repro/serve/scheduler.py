"""Slot-based continuous-batching scheduler.

A fixed-capacity decode batch of ``n_slots`` rows; requests are admitted
into free slots as they arrive (their prompt is prefilled INTO the live
cache at that batch row via ``ModelAPI.prefill_at``), every live slot
advances one token per tick through a single jitted decode step with a
per-slot index vector, and slots retire on EOS / max-token budget, freeing
the row for the next waiting request.  Rows are fully independent in
attention (masked by each slot's own fill level), so a request's tokens are
identical whether it runs one-shot or staggered through a live batch —
tests/test_serving.py asserts this token-for-token.  (One exception:
MoE models under capacity-dropping dispatch — ``GROUPED_IMPL['impl'] ==
'capacity'`` — route parked rows' dummy tokens through the same expert
capacity budget, which can perturb live rows; the constructor warns.  The
default exact 'ragged' dispatch is row-independent.)

Time is measured in scheduler *ticks* (one decode step per tick), which
keeps admission order deterministic and lets tests/benchmarks replay
staggered arrival traces exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .sampling import GenerationResult, Request, sample_token


@dataclasses.dataclass
class _Slot:
    """Book-keeping for one live request occupying one batch row."""
    req: Request
    index: int                    # fill level: next cache write position
    last_tok: int
    generated: List[int]
    admitted_tick: int

    @property
    def key(self):
        return jax.random.PRNGKey(self.req.sampling.seed)


class Scheduler:
    """Continuous batching over a :class:`ServeEngine`.

    ``max_len`` is the per-slot cache width; a request needs
    ``prompt_width + max_new_tokens - 1 <= max_len`` positions.  The decode
    state is created lazily on the first admission (the first prompt is
    tiled across all rows so the state tree — cache layout, enc-dec
    encoder buffer — comes straight from the model's own prefill)."""

    def __init__(self, engine, n_slots: int = 8, max_len: int = 256):
        self.engine = engine
        self.n_slots = n_slots
        self.max_len = max_len
        cfg = engine.api.cfg
        if cfg.n_experts:
            from ..models.moe import GROUPED_IMPL
            if GROUPED_IMPL["impl"] == "capacity":
                import warnings
                warnings.warn(
                    "continuous batching with capacity-dropping MoE "
                    "dispatch: parked slots' dummy tokens compete for "
                    "expert capacity, so live requests may diverge from "
                    "one-shot generate(); use GROUPED_IMPL['impl']="
                    "'ragged' for exact parity", stacklevel=3)
        self.state: Any = None
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.waiting: List[Request] = []
        self.tick = 0
        self.results: Dict[int, GenerationResult] = {}

    # ---- submission ------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.uid in self.results or \
                any(r.uid == req.uid for r in self.waiting) or \
                any(s is not None and s.req.uid == req.uid
                    for s in self.slots):
            raise ValueError(f"duplicate request uid {req.uid}")
        need = self.engine.prompt_width(req.inputs) + \
            req.sampling.max_new_tokens - 1
        if need > self.max_len:
            raise ValueError(
                f"request {req.uid} needs {need} cache positions, "
                f"scheduler max_len is {self.max_len}")
        self.waiting.append(req)
        self.waiting.sort(key=lambda r: r.arrival)

    # ---- admission -------------------------------------------------------
    def _first_token(self, slot: _Slot, logits_row) -> None:
        sp = slot.req.sampling
        key = jax.random.fold_in(slot.key, 0) if sp.temperature > 0 else None
        tok = int(sample_token(logits_row, sp, key))
        slot.generated.append(tok)
        slot.last_tok = tok

    def _admit_into(self, i: int, req: Request) -> None:
        inputs = req.inputs
        pw = self.engine.prompt_width(inputs)
        if self.state is None:
            # Lazy state init: prefill the first prompt ONCE at full cache
            # width, then broadcast its state rows across all slots (rows
            # are identical by construction, so this matches an n_slots-way
            # tiled prefill at 1/n_slots the compute).
            extra = self.max_len - pw
            logits, sub = self.engine.prefill(inputs, extra_slots=extra,
                                              place_state=False)
            state = dict(sub)
            state["cache"] = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(
                    x, (x.shape[0], self.n_slots, *x.shape[2:])),
                sub["cache"])
            if "enc_out" in sub:
                state["enc_out"] = jnp.broadcast_to(
                    sub["enc_out"], (self.n_slots, *sub["enc_out"].shape[1:]))
            self.state = self.engine._shard_state(state, self.n_slots)
            row = logits[0]
        else:
            logits, self.state = self.engine.prefill_at(inputs, self.state,
                                                        jnp.asarray(i))
            row = logits[0]
        slot = _Slot(req=req, index=pw, last_tok=0, generated=[],
                     admitted_tick=self.tick)
        self._first_token(slot, row)
        self.slots[i] = slot
        self._maybe_retire(i)

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if not self.waiting or self.waiting[0].arrival > self.tick:
                return
            if self.slots[i] is None:
                self._admit_into(i, self.waiting.pop(0))

    # ---- retirement ------------------------------------------------------
    def _maybe_retire(self, i: int) -> None:
        slot = self.slots[i]
        sp = slot.req.sampling
        stop = sp.eos_id is not None and slot.generated[-1] == sp.eos_id
        length = len(slot.generated) >= sp.max_new_tokens
        if stop or length:
            self.results[slot.req.uid] = GenerationResult(
                uid=slot.req.uid, tokens=list(slot.generated),
                finish_reason="stop" if stop else "length",
                prompt_len=slot.req.inputs["tokens"].shape[1],
                admitted_tick=slot.admitted_tick,
                finished_tick=self.tick)
            self.slots[i] = None

    # ---- one tick --------------------------------------------------------
    def step(self) -> None:
        """Admit what has arrived, then advance every live slot one token."""
        self._admit()
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if live:
            toks = np.zeros((self.n_slots, 1), np.int32)
            # parked rows write their (ignored) K/V at the last position,
            # which stays masked by the row's fill level until overwritten
            idx = np.full((self.n_slots,), self.max_len - 1, np.int32)
            for i in live:
                toks[i, 0] = self.slots[i].last_tok
                idx[i] = self.slots[i].index
            logits, self.state = self.engine.decode(
                jnp.asarray(toks), self.state, jnp.asarray(idx))
            lg = np.asarray(logits)       # one host transfer per tick
            for i in live:
                slot = self.slots[i]
                sp = slot.req.sampling
                if sp.temperature > 0:
                    key = jax.random.fold_in(slot.key, len(slot.generated))
                    tok = int(sample_token(jnp.asarray(lg[i]), sp, key))
                else:
                    tok = int(lg[i].argmax())
                slot.generated.append(tok)
                slot.last_tok = tok
                slot.index += 1
                self._maybe_retire(i)
        self.tick += 1

    # ---- drive to completion --------------------------------------------
    def run(self, requests: List[Request]) -> List[GenerationResult]:
        """Submit ``requests`` and tick until all have finished; results
        come back in the order the requests were given."""
        for r in requests:
            self.submit(r)
        while self.waiting or any(s is not None for s in self.slots):
            self.step()
        return [self.results[r.uid] for r in requests]
