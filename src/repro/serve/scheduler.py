"""Slot-based continuous-batching scheduler with a paged KV option.

A fixed-capacity decode batch of ``n_slots`` rows; requests are admitted
into free slots as they arrive (their prompt is prefilled INTO the live
cache at that batch row), every live slot advances one token per tick
through a single jitted decode step with a per-slot index vector, and
slots retire on EOS / max-token budget, freeing the row for the next
waiting request.  Rows are fully independent in attention (masked by each
slot's own fill level), so a request's tokens are identical whether it
runs one-shot or staggered through a live batch — tests/test_serving.py
asserts this token-for-token.  (One exception: MoE models under
capacity-dropping dispatch — ``GROUPED_IMPL['impl'] == 'capacity'`` —
route parked rows' dummy tokens through the same expert capacity budget,
which can perturb live rows; the constructor warns.  The default exact
'ragged' dispatch is row-independent.)

Two extensions over the fixed-width layout (both default-off and
token-identical to it):

* ``page_size > 0`` — **paged KV cache**: instead of every slot owning a
  contiguous ``max_len``-wide cache row, K/V live in a global pool of
  fixed-size pages (same int8 / nibble-packed int4 + per-token-scale
  at-rest format) addressed through per-slot block tables.  The scheduler
  owns a host-side free list (page 0 is the reserved trash page that
  parked slots write into): a request is admitted when its worst-case
  page total fits the pool's free-minus-reserved headroom, takes only its
  prompt's pages up front, grows one page at a time as decode crosses
  block boundaries (drawing from its reservation — mid-decode exhaustion
  is impossible by construction), and returns everything on retirement —
  so resident cache bytes track the tokens actually held, not
  ``n_slots * max_len`` worst case.

* ``prefill_chunk > 0`` — **chunked prefill**: prompts longer than the
  chunk width are inserted over several ticks (one chunk per tick via
  ``ModelAPI.prefill_chunk_at``, attending over the slot's cached prefix)
  interleaved with the other slots' decode steps, instead of one
  monolithic latency-spike prefill.  The final chunk is padded to the
  chunk width so chunk shapes compile once; padded positions are masked
  until decode overwrites them.

Three production extensions on top of the paged pool (all token-identical
to the baseline paths):

* **priority classes** — ``SamplingParams.priority`` orders the waiting
  queue (higher first, ties by arrival tick then submission order), and
  admission *skips over* requests the pool cannot host yet instead of
  head-of-line stalling behind one oversized request.

* ``overcommit > 1`` — **reservation overcommit with preemption**:
  admission may promise up to ``overcommit x`` the pool's physical
  capacity in worst-case reservations.  When decode growth then finds
  the free list empty, the lowest-priority / most recently admitted
  victim slot is **parked**: its pool pages and per-slot state rows are
  snapshotted to host memory bit-for-bit (``ServeEngine.park_slot`` — a
  plain ``np.asarray`` of the quantized-at-rest pages, no dequant), its
  pages return to the free list, and the request rejoins the waiting
  queue to resume later through the same block-table insert path
  (``restore_slot``).  The parked round-trip is bit-identical, so
  resumed requests keep exact token parity.

* ``prefix_cache=True`` — **content-addressed prefix caching**: every
  *complete* prompt page (all ``page_size`` tokens inside the prompt,
  never written again) is keyed by a chained token-content hash in a
  refcounted :class:`PrefixCache`.  A later request whose prompt starts
  with the same tokens aliases the shared read-only pages through its
  block table and prefills only the remaining suffix — a hot system
  prompt costs ONE set of pool pages across every concurrent request
  using it.  Writes can never land on a shared page (the hashed region
  always ends at least one token before the first decode write); a
  defensive copy-on-write guard (``_cow_from``) backs the invariant and
  the ``PX2`` contract rule proves it.  Refcounts drop at retirement /
  parking; a page whose count reaches zero returns to the free list, so
  the pool still drains leak-free.

Time is measured in scheduler *ticks* (one decode step per tick), which
keeps admission order deterministic and lets tests/benchmarks replay
staggered arrival traces exactly.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .sampling import GenerationResult, Request, sample_token


@dataclasses.dataclass
class _Slot:
    """Book-keeping for one live request occupying one batch row."""
    req: Request
    index: int                    # fill level: next cache write position
    last_tok: int
    generated: List[int]
    admitted_tick: int
    pages: List[int] = dataclasses.field(default_factory=list)
    reserve_left: int = 0         # growth pages still drawable from pool
    # queued prompt chunks: (inputs, start, last-logit column or None)
    chunks: List[tuple] = dataclasses.field(default_factory=list)
    # refcounted prefix-cache pages aliased at the block-table head; the
    # slot's own pages follow at blocks [n_shared, n_shared + len(pages))
    shared_pages: List[int] = dataclasses.field(default_factory=list)
    # chained content hashes of the prompt's sharable full pages
    prefix_hashes: List[bytes] = dataclasses.field(default_factory=list)

    @property
    def key(self):
        return jax.random.PRNGKey(self.req.sampling.seed)

    @property
    def n_shared(self) -> int:
        return len(self.shared_pages)

    @property
    def n_blocks(self) -> int:
        return len(self.shared_pages) + len(self.pages)

    @property
    def block_pages(self) -> List[int]:
        return self.shared_pages + self.pages


@dataclasses.dataclass
class _Parked:
    """A preempted request waiting to resume: the bit-exact host snapshot
    of everything its slot held (pool pages + per-slot state rows), plus
    the book-keeping to pick up decoding where it stopped."""
    req: Request
    index: int
    last_tok: int
    generated: List[int]
    admitted_tick: int
    chunks: List[tuple]
    prefix_hashes: List[bytes]
    n_blocks: int                 # block-table entries the snapshot holds
    reserve_need: int             # growth pages still needed after resume
    record: Any                   # ServeEngine.park_slot host snapshot


def _entry_req(entry) -> Request:
    return entry.req if isinstance(entry, _Parked) else entry


def _queue_key(seq_of: Dict[int, int]):
    """Waiting-queue order: priority desc, arrival asc, submission asc.
    Parked requests keep their original request's key (no re-queue
    penalty, no queue jumping)."""
    def key(entry):
        r = _entry_req(entry)
        return (-r.sampling.priority, r.arrival, seq_of[r.uid])
    return key


class PageAllocator:
    """Host-side free list over the global page pool.

    Page 0 is reserved as the trash page (parked-slot scratch writes and
    unallocated block-table entries), so capacity ``n_pages`` serves at
    most ``n_pages - 1`` live pages.  The free list is a min-heap, so
    allocation pops the globally lowest free id no matter how slots
    churned — traces are deterministic and replayable.

    Admission control is *reservation*-based: a request only enters a slot
    when its worst-case page total (prompt + generation budget) fits in
    the reservation headroom, and its not-yet-drawn tail is recorded in
    ``reserved``.  Pages are still *allocated* lazily (prompt pages at
    admission, decode pages one block at a time), so ``in_use``/
    ``peak_in_use`` track tokens actually held.  With the default
    ``overcommit=1.0`` the headroom is physical (``free - reserved``) and
    mid-decode growth can never exhaust the pool; ``overcommit > 1``
    admits up to that multiple of physical capacity in promises, and the
    scheduler parks victims when :meth:`alloc` then comes up empty."""

    def __init__(self, n_pages: int, overcommit: float = 1.0):
        if n_pages < 2:
            raise ValueError(f"page pool needs >= 2 pages (one is the "
                             f"reserved trash page), got {n_pages}")
        if overcommit < 1.0:
            raise ValueError(f"overcommit must be >= 1.0, got {overcommit}")
        self.n_pages = n_pages
        self.overcommit = overcommit
        self._free = list(range(1, n_pages))        # already heap-ordered
        self.reserved = 0          # promised to live slots, not yet drawn
        self.peak_in_use = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.n_pages - 1 - len(self._free)

    def can_admit(self, total_pages: int, now: int = 0) -> bool:
        """``total_pages`` new worst-case promises fit the (possibly
        overcommitted) reservation budget, and the ``now`` pages needed
        immediately are physically on the free list."""
        cap = int((self.n_pages - 1) * self.overcommit)
        return (total_pages + self.in_use + self.reserved <= cap
                and now <= len(self._free))

    def alloc(self, n: int, from_reserve: int = 0) -> Optional[List[int]]:
        """n pages (releasing ``from_reserve`` of the caller's
        reservation), or None if the free list cannot satisfy it."""
        if n > len(self._free):
            return None
        self.reserved -= from_reserve
        pages = [heapq.heappop(self._free) for _ in range(n)]
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pages

    def release(self, pages: List[int], from_reserve: int = 0) -> None:
        self.reserved -= from_reserve
        for p in pages:
            heapq.heappush(self._free, p)


class PrefixCache:
    """Content-addressed, refcounted registry of read-only prompt pages.

    Keys are *chained* hashes: page j's key digests page j-1's key plus
    page j's tokens, so a hit at block j certifies the whole prefix
    [0, (j+1) * page_size) matches and lookups stop at the first miss
    (shared blocks are always a contiguous table-row prefix, which the
    ``PA3``/``PX2`` contracts rely on).  Ownership of a registered page
    transfers here: the registering slot holds one reference like any
    later aliaser, and :meth:`release` hands the page id back to the
    caller (for the allocator's free list) once the last reference
    drops — so a drained scheduler always ends at zero refcounts and
    zero live pages."""

    def __init__(self):
        self._page_of: Dict[bytes, int] = {}
        self._hash_of: Dict[int, bytes] = {}
        self._refs: Dict[int, int] = {}
        self.hits = 0              # page-granular hit counter
        self.lookups = 0

    def __len__(self) -> int:
        return len(self._page_of)

    @property
    def refcounts(self) -> Dict[int, int]:
        return dict(self._refs)

    @property
    def outstanding_refs(self) -> int:
        return sum(self._refs.values())

    @staticmethod
    def chain(prev: bytes, tokens: np.ndarray) -> bytes:
        return hashlib.sha256(
            prev + np.ascontiguousarray(tokens, np.int32).tobytes()).digest()

    def lookup(self, h: bytes) -> Optional[int]:
        self.lookups += 1
        page = self._page_of.get(h)
        if page is not None:
            self.hits += 1
        return page

    def acquire(self, page: int) -> None:
        self._refs[page] += 1

    def register(self, h: bytes, page: int) -> None:
        """Publish ``page`` under ``h``; the registering slot holds the
        first reference."""
        if h in self._page_of:
            raise ValueError(f"hash already registered to page "
                             f"{self._page_of[h]}")
        self._page_of[h] = page
        self._hash_of[page] = h
        self._refs[page] = 1

    def release(self, page: int) -> bool:
        """Drop one reference; True when the page just became free (the
        caller returns it to the allocator)."""
        self._refs[page] -= 1
        if self._refs[page] > 0:
            return False
        del self._refs[page]
        del self._page_of[self._hash_of.pop(page)]
        return True


def _paged_pool_bytes(cache) -> int:
    """Total at-rest bytes of every page-pool leaf in a cache tree."""
    if isinstance(cache, dict):
        if "table" in cache:
            return sum(int(leaf.nbytes) for leaf in
                       jax.tree_util.tree_leaves(cache["pages"]))
        return sum(_paged_pool_bytes(v) for v in cache.values())
    return 0


def _kv_resident_bytes(cache) -> int:
    """At-rest bytes of a contiguous cache's KV leaves (k/v + scales)."""
    if isinstance(cache, dict):
        if "k" in cache and "v" in cache:
            return sum(int(leaf.nbytes) for leaf in
                       jax.tree_util.tree_leaves(cache))
        return sum(_kv_resident_bytes(v) for v in cache.values())
    return 0


# families whose paged KV cache is purely positional AND whose prompts are
# token-only: prefix pages can be shared by token-content hash alone.
# (vlm prompts embed per-request vision K/V in the hashed region; enc-dec
# carries a per-slot encoder buffer; ssm/hybrid carry recurrent rows.)
_PREFIX_CACHE_FAMILIES = ("dense", "moe")


class Scheduler:
    """Continuous batching over a :class:`ServeEngine`.

    ``max_len`` is the per-slot cache width; a request needs
    ``prompt_width + max_new_tokens - 1 <= max_len`` positions.  With the
    fixed-width cache the decode state is created lazily on the first
    admission (the first prompt is tiled across all rows so the state
    tree — cache layout, enc-dec encoder buffer — comes straight from the
    model's own prefill).  Paged / chunked modes build a zeroed state via
    ``ModelAPI.init_decode_state`` instead and insert every prompt —
    including the first — through the same block-table write path.

    ``overcommit`` (> 1, paged only) admits more worst-case reservations
    than the pool physically holds and parks victims on exhaustion;
    ``prefix_cache`` (paged only) shares complete prompt pages across
    requests by content hash.  Both are token-identical to the baseline
    (tests/test_serving_stress.py drives randomized workloads through
    them against one-shot ``generate``)."""

    def __init__(self, engine, n_slots: int = 8, max_len: int = 256,
                 page_size: int = 0, n_pages: Optional[int] = None,
                 prefill_chunk: int = 0, overcommit: float = 1.0,
                 prefix_cache: bool = False):
        self.engine = engine
        self.n_slots = n_slots
        self.max_len = max_len
        cfg = engine.api.cfg
        if page_size and cfg.family == "ssm":
            import warnings
            warnings.warn("family 'ssm' has no KV cache to page; "
                          "page_size ignored", stacklevel=3)
            page_size = 0
        if cfg.n_experts:
            from ..models.moe import GROUPED_IMPL
            if GROUPED_IMPL["impl"] == "capacity":
                import warnings
                warnings.warn(
                    "continuous batching with capacity-dropping MoE "
                    "dispatch: parked slots' dummy tokens compete for "
                    "expert capacity, so live requests may diverge from "
                    "one-shot generate(); use GROUPED_IMPL['impl']="
                    "'ragged' for exact parity", stacklevel=3)
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk
        self.paged = page_size > 0
        if overcommit > 1.0 and not self.paged:
            raise ValueError("overcommit > 1 needs a paged cache "
                             "(page_size > 0): preemption parks pool "
                             "pages, fixed-width slots have none")
        if prefix_cache and not self.paged:
            raise ValueError("prefix_cache needs a paged cache "
                             "(page_size > 0): sharing happens through "
                             "the block table")
        if prefix_cache and cfg.family not in _PREFIX_CACHE_FAMILIES:
            import warnings
            warnings.warn(
                f"prefix_cache needs a purely positional token-only KV "
                f"cache; family {cfg.family!r} carries per-request "
                f"vision/encoder/recurrent state — disabled", stacklevel=3)
            prefix_cache = False
        self.overcommit = overcommit
        if self.paged:
            self.nb = -(-max_len // page_size)
            self.total_len = self.nb * page_size
            self.allocator = PageAllocator(n_pages or 1 + n_slots * self.nb,
                                           overcommit=overcommit)
            self.tables = np.zeros((n_slots, self.nb), np.int32)
        else:
            self.nb = 0
            self.total_len = max_len
            self.allocator = None
            self.tables = None
        self.prefix_cache: Optional[PrefixCache] = \
            PrefixCache() if prefix_cache else None
        self._tables_dirty = False
        # paged / chunked prompts go through the zero-state insertion path
        self._insert_path = self.paged or prefill_chunk > 0
        self.state: Any = None
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.waiting: List[Any] = []       # Request | _Parked, queue-ordered
        self._seq_of: Dict[int, int] = {}  # uid -> submission sequence
        self.tick = 0
        self.results: Dict[int, GenerationResult] = {}
        # speculative-decode accounting (acceptance rate, bench rows)
        self.spec_stats: Dict[str, int] = {
            "rounds": 0, "drafted": 0, "accepted_drafts": 0, "emitted": 0}
        # priority / preemption / prefix-cache accounting
        self.sched_stats: Dict[str, int] = {
            "preemptions": 0, "resumes": 0, "cow_copies": 0,
            "prefix_lookups": 0, "prefix_hits": 0, "prefix_hit_tokens": 0,
            "prefix_pages_registered": 0}

    # ---- submission ------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.uid in self.results or \
                any(_entry_req(e).uid == req.uid for e in self.waiting) or \
                any(s is not None and s.req.uid == req.uid
                    for s in self.slots):
            raise ValueError(f"duplicate request uid {req.uid}")
        need = self.engine.prompt_width(req.inputs) + \
            req.sampling.max_new_tokens - 1
        if need > self.max_len:
            raise ValueError(
                f"request {req.uid} needs {need} cache positions, "
                f"scheduler max_len is {self.max_len}")
        if self.paged:
            pages = -(-need // self.page_size)
            if pages > self.allocator.n_pages - 1:
                raise ValueError(
                    f"request {req.uid} needs {pages} pages, pool capacity "
                    f"is {self.allocator.n_pages - 1} live pages "
                    f"(overcommit promises concurrency, not capacity)")
        self._seq_of[req.uid] = len(self._seq_of)
        self.waiting.append(req)
        self._sort_waiting()

    def _sort_waiting(self) -> None:
        self.waiting.sort(key=_queue_key(self._seq_of))

    # ---- admission -------------------------------------------------------
    def _first_token(self, slot: _Slot, logits_row) -> None:
        sp = slot.req.sampling
        key = jax.random.fold_in(slot.key, 0) if sp.temperature > 0 else None
        tok = int(sample_token(logits_row, sp, key))
        slot.generated.append(tok)
        slot.last_tok = tok

    def _flush_tables(self) -> None:
        if self._tables_dirty:
            self.state = self.engine.set_tables(self.state, self.tables)
            self._tables_dirty = False

    def _plan_chunks(self, req: Request, skip: int = 0) -> List[tuple]:
        """Split a prompt into (inputs, start, last-col) insertion chunks.

        The vision prefix / encoder frames ride the first chunk (which
        therefore starts at cache position 0); later chunks carry tokens
        only and start at their cache position (vision offset included).
        Only the final chunk reports a logits column (the last *real*
        token — the final chunk is zero-padded to the chunk width so every
        chunk compiles to one shape).

        ``skip > 0`` (prefix-cache hit) drops the first ``skip`` tokens:
        their K/V already sit in aliased shared pages, so insertion
        starts at cache position ``skip`` and attends over the shared
        prefix exactly as later chunks attend over earlier ones.  Hits
        only happen for token-only positional-KV families, so the
        vision/frames first-chunk and recurrent-state special cases never
        meet a non-zero ``skip``.

        Recurrent-state families (ssm, hybrid) always insert monolithic:
        their state has no fill-level masking, so padded tokens would
        pollute it, and the rwkv/mamba chunked scans are only
        FP-*approximately* invariant to the chunk decomposition — not the
        bit-exact parity this scheduler guarantees."""
        inputs = req.inputs
        toks = np.asarray(inputs["tokens"])
        p = toks.shape[1]
        cw = self.prefill_chunk
        cfg = self.engine.api.cfg
        tv = cfg.vision_tokens if cfg.family == "vlm" else 0
        if skip and (cw <= 0 or p - skip <= cw):
            return [({"tokens": jnp.asarray(toks[:, skip:])}, skip, None)]
        if cw <= 0 or p <= cw or cfg.family in ("ssm", "hybrid"):
            return [(inputs, 0, None)]
        chunks = []
        n_c = -(-(p - skip) // cw)
        for c in range(n_c):
            lo, hi = skip + c * cw, min(skip + (c + 1) * cw, p)
            w = hi - lo
            ct = toks[:, lo:hi]
            last = c == n_c - 1
            if last and w < cw:
                # pad to the chunk width for one compile shape, but never
                # past the slot's cache extent: an overflowing write would
                # clamp (contiguous) or alias in-page offsets (paged) onto
                # real prompt K/V
                padded = min(cw, self.total_len - (tv + lo))
                ct = np.pad(ct, ((0, 0), (0, padded - w)))
            b = {"tokens": jnp.asarray(ct)}
            first = c == 0 and skip == 0
            if first:
                for extra in ("vision_embeds", "frames"):
                    if extra in inputs:
                        b[extra] = inputs[extra]
            start = 0 if first else tv + lo
            col = ((tv if first else 0) + w - 1) if last else None
            chunks.append((b, start, col))
        return chunks

    # ---- prefix cache ----------------------------------------------------
    def _prefix_hashes(self, req: Request) -> List[bytes]:
        """Chained content hashes of the prompt's *sharable* full pages.

        A page is sharable iff its whole ``page_size``-token range lies
        inside the prompt AND strictly before the last prompt token — the
        final position must always be recomputed to produce the request's
        first-token logits, so the hashed region ends at the largest page
        boundary <= prompt_width - 1 and no write (suffix prefill at
        ``skip`` or decode at ``prompt_width``) can ever land on a shared
        page."""
        toks = np.asarray(req.inputs["tokens"])
        pw = toks.shape[1]
        ps = self.page_size
        limit = ((pw - 1) // ps) * ps
        hashes, h = [], b""
        for j in range(limit // ps):
            h = PrefixCache.chain(h, toks[:, j * ps:(j + 1) * ps])
            hashes.append(h)
        return hashes

    def _register_prompt_pages(self, i: int) -> None:
        """Publish slot ``i``'s freshly prefetched full prompt pages into
        the prefix cache (called once its prompt is fully inserted —
        earlier registration would let another slot alias pages whose
        content hasn't been written yet).  Ownership of each registered
        page moves to the cache; the slot keeps one reference, so its
        block layout (shared prefix, then owned pages) stays contiguous."""
        if self.prefix_cache is None:
            return
        s = self.slots[i]
        for j in range(s.n_shared, len(s.prefix_hashes)):
            h = s.prefix_hashes[j]
            if self.prefix_cache._page_of.get(h) is not None:
                # a same-prefix sibling registered this page range first
                # (both admitted before either finished prefill); keep
                # ours private — a later register would break the
                # hash -> one-page mapping
                break
            page = s.pages.pop(0)
            self.prefix_cache.register(h, page)
            s.shared_pages.append(page)
            self.sched_stats["prefix_pages_registered"] += 1

    def _decref(self, page: int) -> None:
        if self.prefix_cache.release(page):
            self.allocator.release([page])

    def _admit_into(self, i: int, req: Request) -> bool:
        """Place ``req`` into free slot ``i``; False if the page pool
        cannot cover its prompt yet (request stays queued)."""
        inputs = req.inputs
        pw = self.engine.prompt_width(inputs)
        if self._insert_path:
            if self.state is None:
                self.state = self.engine.init_decode_state(
                    inputs, self.n_slots, self.max_len,
                    page_size=self.page_size,
                    n_pages=self.allocator.n_pages if self.paged else None)
            if "frames" in inputs and \
                    inputs["frames"].shape[1] != \
                    self.state["enc_out"].shape[1]:
                raise ValueError(
                    "enc-dec slot insertion needs the same encoder length "
                    f"as the live batch: {inputs['frames'].shape[1]} != "
                    f"{self.state['enc_out'].shape[1]}")
            reserve, hits, hashes = 0, [], []
            if self.paged:
                if self.prefix_cache is not None:
                    hashes = self._prefix_hashes(req)
                    for h in hashes:
                        page = self.prefix_cache.lookup(h)
                        self.sched_stats["prefix_lookups"] += 1
                        if page is None:
                            break
                        hits.append(page)
                need = pw + req.sampling.max_new_tokens - 1
                total = -(-need // self.page_size)
                prompt_pages = min(-(-pw // self.page_size), total)
                fresh = prompt_pages - len(hits)
                if not self.allocator.can_admit(total - len(hits),
                                                now=fresh):
                    return False
                pages = self.allocator.alloc(fresh)
                for page in hits:
                    self.prefix_cache.acquire(page)
                if hits:
                    self.sched_stats["prefix_hits"] += len(hits)
                    self.sched_stats["prefix_hit_tokens"] += \
                        len(hits) * self.page_size
                reserve = total - prompt_pages
                self.allocator.reserved += reserve
                self.tables[i, :len(hits)] = hits
                self.tables[i, len(hits):prompt_pages] = pages
                self._tables_dirty = True
            else:
                pages = []
            skip = len(hits) * self.page_size
            self.slots[i] = _Slot(req=req, index=pw, last_tok=0,
                                  generated=[], admitted_tick=self.tick,
                                  pages=pages, reserve_left=reserve,
                                  chunks=self._plan_chunks(req, skip=skip),
                                  shared_pages=list(hits),
                                  prefix_hashes=hashes)
            return True
        # ---- legacy fixed-width path (monolithic prefill) ---------------
        if self.state is None:
            # Lazy state init: prefill the first prompt ONCE at full cache
            # width, then broadcast its state rows across all slots (rows
            # are identical by construction, so this matches an n_slots-way
            # tiled prefill at 1/n_slots the compute).
            extra = self.max_len - pw
            logits, sub = self.engine.prefill(inputs, extra_slots=extra,
                                              place_state=False)
            state = dict(sub)
            state["cache"] = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(
                    x, (x.shape[0], self.n_slots, *x.shape[2:])),
                sub["cache"])
            if "enc_out" in sub:
                state["enc_out"] = jnp.broadcast_to(
                    sub["enc_out"], (self.n_slots, *sub["enc_out"].shape[1:]))
            self.state = self.engine._shard_state(state, self.n_slots)
            row = logits[0]
        else:
            logits, self.state = self.engine.prefill_at(inputs, self.state,
                                                        jnp.asarray(i))
            row = logits[0]
        slot = _Slot(req=req, index=pw, last_tok=0, generated=[],
                     admitted_tick=self.tick)
        self._first_token(slot, row)
        self.slots[i] = slot
        self._maybe_retire(i)
        return True

    def _resume_into(self, i: int, pk: _Parked) -> bool:
        """Restore a parked request into free slot ``i``: re-allocate its
        block pages, write the host snapshot back bit-for-bit, and pick
        up decoding (or remaining prefill chunks) where it stopped."""
        if not self.allocator.can_admit(pk.n_blocks + pk.reserve_need,
                                        now=pk.n_blocks):
            return False
        pages = self.allocator.alloc(pk.n_blocks)
        self.allocator.reserved += pk.reserve_need
        self.state = self.engine.restore_slot(self.state, i, pages,
                                              pk.record)
        self.tables[i, :] = 0
        self.tables[i, :len(pages)] = pages
        self._tables_dirty = True
        # resumed pages are private even if some were shared before the
        # park (their refs were dropped then; the snapshot carried the
        # content instead), so the slot re-enters fully owned
        self.slots[i] = _Slot(req=pk.req, index=pk.index,
                              last_tok=pk.last_tok, generated=pk.generated,
                              admitted_tick=pk.admitted_tick, pages=pages,
                              reserve_left=pk.reserve_need,
                              chunks=pk.chunks,
                              prefix_hashes=pk.prefix_hashes)
        self.sched_stats["resumes"] += 1
        return True

    def _admit(self) -> None:
        """Fill free slots from the priority/arrival-ordered queue.

        Requests the pool cannot host yet are *skipped over* — a blocked
        oversized (or parked) request must not head-of-line stall
        admissible ones behind it; it stays queued at its priority rank
        and is retried every tick."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free or not self.waiting:
            return
        for entry in list(self.waiting):
            if not free:
                return
            if _entry_req(entry).arrival > self.tick:
                continue
            if isinstance(entry, _Parked):
                ok = self._resume_into(free[0], entry)
            else:
                ok = self._admit_into(free[0], entry)
            if ok:
                free.pop(0)
                self.waiting.remove(entry)

    # ---- chunked / paged prompt insertion --------------------------------
    def _advance_prefills(self) -> None:
        """One prompt chunk per mid-prefill slot per tick; the final chunk
        samples the request's first token (as monolithic admission does)
        and publishes the prompt's full pages to the prefix cache."""
        for i, s in enumerate(self.slots):
            if s is None or not s.chunks:
                continue
            self._flush_tables()
            batch, start, col = s.chunks.pop(0)
            logits, self.state = self.engine.prefill_chunk_at(
                batch, self.state, i, start)
            if not s.chunks:
                self._register_prompt_pages(i)
                self._first_token(s, logits[0, -1 if col is None else col])
                self._maybe_retire(i)

    # ---- preemption ------------------------------------------------------
    def _pick_victim(self, exclude: int) -> Optional[int]:
        """Lowest-priority live slot (ties: most recently admitted, then
        highest row) other than ``exclude`` — the request that loses the
        least progress and outranks the fewest others."""
        candidates = [i for i, s in enumerate(self.slots)
                      if s is not None and i != exclude]
        if not candidates:
            return None
        return min(candidates,
                   key=lambda i: (self.slots[i].req.sampling.priority,
                                  -self.slots[i].admitted_tick, -i))

    def _park(self, i: int) -> None:
        """Swap slot ``i`` out to host memory: snapshot its pool pages and
        per-slot state rows bit-for-bit (quantized at-rest bytes copied
        as-is — no dequant round trip), return its pages + reservation to
        the allocator, drop its shared-page refs (the snapshot carries
        their content, so resume never depends on cache survival), and
        re-queue the request at its original priority/arrival rank."""
        s = self.slots[i]
        rec = self.engine.park_slot(self.state, i, s.block_pages)
        for page in s.shared_pages:
            self._decref(page)
        self.allocator.release(s.pages, from_reserve=s.reserve_left)
        self.tables[i, :] = 0
        self._tables_dirty = True
        need = self.engine.prompt_width(s.req.inputs) + \
            s.req.sampling.max_new_tokens - 1
        nb_total = -(-need // self.page_size)
        self.waiting.append(_Parked(
            req=s.req, index=s.index, last_tok=s.last_tok,
            generated=s.generated, admitted_tick=s.admitted_tick,
            chunks=s.chunks, prefix_hashes=s.prefix_hashes,
            n_blocks=s.n_blocks, reserve_need=nb_total - s.n_blocks,
            record=rec))
        self._sort_waiting()
        self.slots[i] = None
        self.sched_stats["preemptions"] += 1

    # ---- copy-on-write ---------------------------------------------------
    def _cow_from(self, i: int, blk: int) -> None:
        """Divergent-write guard: copy slot ``i``'s shared blocks
        ``blk..`` into fresh private pages before a write can land there.
        Structurally unreachable under the hashed-region rule (shared
        pages always end before the first writable position — PX2), but
        kept as the enforcement backstop the contract describes."""
        s = self.slots[i]
        moved = []
        for j in range(blk, s.n_shared):
            src = s.shared_pages[j]
            page = self.allocator.alloc(1)
            assert page is not None, "copy-on-write needs a free page"
            self.state = self.engine.copy_pool_page(self.state, src,
                                                    page[0])
            self.tables[i, j] = page[0]
            self._tables_dirty = True
            moved.append(page[0])
            self._decref(src)
            self.sched_stats["cow_copies"] += 1
        s.pages = moved + s.pages
        del s.shared_pages[blk:]

    # ---- paged growth ----------------------------------------------------
    def _grow_pages(self, live: List[int], lookahead: int = 0) -> List[int]:
        """Allocate pages for every slot whose upcoming writes cross block
        boundaries; returns the slots still live afterwards.  Plain decode
        advances one token per tick (at most one page per slot); a
        speculative round writes up to ``lookahead`` positions past the
        fill level in one tick, so growth may claim several pages — all
        from the slot's admission-time reservation.  Under ``overcommit
        <= 1`` the free list can never come up short here; beyond it, an
        empty free list parks the lowest-priority victim (or, when every
        other page is this slot's own, the slot itself) and retries."""
        still = []
        for i in live:
            s = self.slots[i]
            if s is None:
                continue           # parked as a victim earlier this tick
            wb = s.index // self.page_size
            if wb < s.n_shared:
                self._cow_from(i, wb)
            blk_hi = (s.index + lookahead) // self.page_size
            parked_self = False
            while s.n_blocks <= blk_hi:
                page = self.allocator.alloc(1, from_reserve=1)
                if page is None:   # failed alloc leaves `reserved` intact
                    victim = self._pick_victim(exclude=i)
                    if victim is None:
                        self._park(i)
                        parked_self = True
                        break
                    self._park(victim)
                    continue
                assert s.reserve_left > 0, \
                    f"reservation accounting broke for slot {i}"
                s.reserve_left -= 1
                blk = s.n_blocks
                s.pages += page
                self.tables[i, blk] = page[0]
                self._tables_dirty = True
            if not parked_self:
                still.append(i)
        return [i for i in still if self.slots[i] is not None]

    # ---- retirement ------------------------------------------------------
    def _maybe_retire(self, i: int) -> None:
        slot = self.slots[i]
        sp = slot.req.sampling
        stop = sp.eos_id is not None and slot.generated[-1] == sp.eos_id
        length = len(slot.generated) >= sp.max_new_tokens
        if stop or length:
            self.results[slot.req.uid] = GenerationResult(
                uid=slot.req.uid, tokens=list(slot.generated),
                finish_reason="stop" if stop else "length",
                prompt_len=slot.req.inputs["tokens"].shape[1],
                admitted_tick=slot.admitted_tick,
                finished_tick=self.tick)
            if self.paged and (slot.block_pages or slot.reserve_left):
                for page in slot.shared_pages:
                    self._decref(page)
                self.allocator.release(slot.pages,
                                       from_reserve=slot.reserve_left)
                self.tables[i, :] = 0
                self._tables_dirty = True
            self.slots[i] = None

    # ---- speculative tick ------------------------------------------------
    def _spec_tick(self, live: List[int]) -> bool:
        """One draft/verify round over the live greedy slots.

        Draft depth is clamped round-wide to the tightest slot's remaining
        token budget minus one (each slot emits at least one verify-chosen
        token), so every cache write — γ draft steps at ``index..index+γ-1``
        plus the (γ+1)-wide verify at ``index`` — stays inside each slot's
        admission-time page reservation.  Rejected draft K/V needs no
        rollback: it sits above the accepted fill level, masked by
        ``kv_len``, and the next round's verify rewrites it at full
        precision before it can ever be unmasked — so the page pool drains
        leak-free.  Returns False (caller runs the plain tick) when no
        draft depth fits."""
        from .autotune.speculative import greedy_verify
        eng = self.engine
        g = min(eng.draft_gamma,
                min(self.slots[i].req.sampling.max_new_tokens
                    - len(self.slots[i].generated) for i in live) - 1)
        if g < 1:
            return False
        if self.paged:
            live = self._grow_pages(live, lookahead=g)
            if not live:
                return True        # every slot parked; the tick still ran
        self._flush_tables()
        toks = np.zeros((self.n_slots, 1), np.int32)
        # parked rows write masked scratch at the last position (paged:
        # the trash page), exactly like the plain tick
        idx = np.full((self.n_slots,), self.total_len - 1, np.int32)
        for i in live:
            toks[i, 0] = self.slots[i].last_tok
            idx[i] = self.slots[i].index
        idx_j = jnp.asarray(idx)
        cur, drafts, state = jnp.asarray(toks), [], self.state
        for j in range(g):
            lg, state = eng.draft_decode(cur, state, idx_j + j)
            cur = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
            drafts.append(cur)
        vtoks = jnp.concatenate([jnp.asarray(toks)] + drafts, axis=1)
        vlogits, self.state = eng.verify(vtoks, state, idx_j)
        accepted, n_draft = greedy_verify(np.asarray(vtoks[:, 1:]),
                                          np.asarray(vlogits))
        self.spec_stats["rounds"] += 1
        for i in live:
            slot = self.slots[i]
            sp = slot.req.sampling
            self.spec_stats["drafted"] += g
            self.spec_stats["accepted_drafts"] += int(n_draft[i])
            for t in accepted[i]:
                slot.generated.append(int(t))
                slot.last_tok = int(t)
                slot.index += 1
                self.spec_stats["emitted"] += 1
                if (sp.eos_id is not None and int(t) == sp.eos_id) or \
                        len(slot.generated) >= sp.max_new_tokens:
                    break                 # discard the rest of the round
            self._maybe_retire(i)
        return True

    # ---- one tick --------------------------------------------------------
    def step(self) -> None:
        """Admit what has arrived, advance mid-prefill slots one chunk,
        then advance every decoding slot one token."""
        self._admit()
        if self._insert_path:
            self._advance_prefills()
        live = [i for i, s in enumerate(self.slots)
                if s is not None and not s.chunks]
        if live and self.engine.speculate_planes and \
                all(self.slots[i].req.sampling.temperature == 0
                    for i in live):
            if self._spec_tick(live):
                self.tick += 1
                return
        if live and self.paged:
            live = self._grow_pages(live)
        if live:
            self._flush_tables()
            toks = np.zeros((self.n_slots, 1), np.int32)
            # parked rows write their (ignored) K/V at the last position —
            # with a paged cache that position routes to the trash page —
            # where it stays masked by the row's fill level
            idx = np.full((self.n_slots,), self.total_len - 1, np.int32)
            for i in live:
                toks[i, 0] = self.slots[i].last_tok
                idx[i] = self.slots[i].index
            logits, self.state = self.engine.decode(
                jnp.asarray(toks), self.state, jnp.asarray(idx))
            lg = np.asarray(logits)       # one host transfer per tick
            for i in live:
                slot = self.slots[i]
                sp = slot.req.sampling
                if sp.temperature > 0:
                    key = jax.random.fold_in(slot.key, len(slot.generated))
                    tok = int(sample_token(jnp.asarray(lg[i]), sp, key))
                else:
                    tok = int(lg[i].argmax())
                slot.generated.append(tok)
                slot.last_tok = tok
                slot.index += 1
                self._maybe_retire(i)
        self.tick += 1

    # ---- reporting -------------------------------------------------------
    def compile_footprint(self, prompt_widths=None) -> List[Any]:
        """Static census of every jit signature this scheduler's workload
        compiles (``analysis.footprint``) — run it *before* serving to
        catch a recompile blowup as a lint failure, not a latency
        mystery.  ``prompt_widths`` defaults to the submitted requests'."""
        from ..analysis.footprint import scheduler_footprint
        return scheduler_footprint(self, prompt_widths)

    def validate(self):
        """Contract-check the live scheduler state: the paged decode tree
        (PC*/PA*) plus the refcount / shared-write / parked-hygiene rules
        (PX1-PX3, ``analysis.contracts.validate_scheduler``)."""
        from ..analysis.contracts import (validate_decode_state,
                                          validate_scheduler)
        findings = list(validate_scheduler(self))
        if self.state is not None:
            refcounted = None if self.prefix_cache is None else \
                self.prefix_cache.refcounts
            findings += validate_decode_state(self.state,
                                              n_slots=self.n_slots,
                                              refcounts=refcounted)
        return findings

    def cache_report(self) -> Dict[str, Any]:
        """Resident-cache accounting (the paged-vs-fixed-width headline).

        ``bytes_in_use_peak`` charges each allocated page its full at-rest
        footprint across every layer; ``fixed_equiv_bytes`` is what the
        same workload would hold resident as ``n_slots`` fixed
        ``max_len``-wide rows."""
        if self.state is None:
            return {"paged": self.paged}
        if not self.paged:
            return {"paged": False,
                    "resident_bytes": _kv_resident_bytes(
                        self.state["cache"])}
        pool_bytes = _paged_pool_bytes(self.state["cache"])
        cap = self.allocator.n_pages
        page_bytes = pool_bytes // cap
        rep = {
            "paged": True,
            "page_size": self.page_size,
            "pool_capacity_pages": cap,
            "pages_in_use": self.allocator.in_use,
            "peak_pages_in_use": self.allocator.peak_in_use,
            "page_bytes": page_bytes,
            "bytes_in_use_peak": self.allocator.peak_in_use * page_bytes,
            # ceil block count: a fixed layout rounds every slot's row up
            # to whole pages too (max_len // page_size undercounts
            # whenever page_size does not divide max_len)
            "fixed_equiv_bytes": page_bytes * self.n_slots * self.nb,
            "overcommit": self.overcommit,
            **{k: v for k, v in self.sched_stats.items()},
        }
        if self.prefix_cache is not None:
            rep["prefix_cached_pages"] = len(self.prefix_cache)
            rep["prefix_outstanding_refs"] = \
                self.prefix_cache.outstanding_refs
        return rep

    # ---- drive to completion --------------------------------------------
    def run(self, requests: List[Request]) -> List[GenerationResult]:
        """Submit ``requests`` and tick until all have finished; results
        come back in the order the requests were given."""
        for r in requests:
            self.submit(r)
        idle = 0
        while self.waiting or any(s is not None for s in self.slots):
            before = len(self.results)
            self.step()
            if any(s is not None for s in self.slots) or \
                    len(self.results) != before or \
                    any(_entry_req(e).arrival >= self.tick
                        for e in self.waiting):
                idle = 0
            else:
                idle += 1          # nothing live, nothing admissible
                if idle > len(self.waiting) + 2:
                    free = self.allocator.free_count if self.paged else "n/a"
                    rsv = self.allocator.reserved if self.paged else 0
                    raise RuntimeError(
                        f"admission deadlock: {len(self.waiting)} queued "
                        f"requests, none admissible (free={free}, "
                        f"reserved={rsv})")
        return [self.results[r.uid] for r in requests]
