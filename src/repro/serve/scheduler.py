"""Slot-based continuous-batching scheduler with a paged KV option.

A fixed-capacity decode batch of ``n_slots`` rows; requests are admitted
into free slots as they arrive (their prompt is prefilled INTO the live
cache at that batch row), every live slot advances one token per tick
through a single jitted decode step with a per-slot index vector, and
slots retire on EOS / max-token budget, freeing the row for the next
waiting request.  Rows are fully independent in attention (masked by each
slot's own fill level), so a request's tokens are identical whether it
runs one-shot or staggered through a live batch — tests/test_serving.py
asserts this token-for-token.  (One exception: MoE models under
capacity-dropping dispatch — ``GROUPED_IMPL['impl'] == 'capacity'`` —
route parked rows' dummy tokens through the same expert capacity budget,
which can perturb live rows; the constructor warns.  The default exact
'ragged' dispatch is row-independent.)

Two extensions over the fixed-width layout (both default-off and
token-identical to it):

* ``page_size > 0`` — **paged KV cache**: instead of every slot owning a
  contiguous ``max_len``-wide cache row, K/V live in a global pool of
  fixed-size pages (same int8 / nibble-packed int4 + per-token-scale
  at-rest format) addressed through per-slot block tables.  The scheduler
  owns a host-side free list (page 0 is the reserved trash page that
  parked slots write into): a request is admitted when its worst-case
  page total fits the pool's free-minus-reserved headroom, takes only its
  prompt's pages up front, grows one page at a time as decode crosses
  block boundaries (drawing from its reservation — mid-decode exhaustion
  is impossible by construction), and returns everything on retirement —
  so resident cache bytes track the tokens actually held, not
  ``n_slots * max_len`` worst case.  When the pool lacks headroom,
  admission waits (head-of-line) until pages free up.

* ``prefill_chunk > 0`` — **chunked prefill**: prompts longer than the
  chunk width are inserted over several ticks (one chunk per tick via
  ``ModelAPI.prefill_chunk_at``, attending over the slot's cached prefix)
  interleaved with the other slots' decode steps, instead of one
  monolithic latency-spike prefill.  The final chunk is padded to the
  chunk width so chunk shapes compile once; padded positions are masked
  until decode overwrites them.

Time is measured in scheduler *ticks* (one decode step per tick), which
keeps admission order deterministic and lets tests/benchmarks replay
staggered arrival traces exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .sampling import GenerationResult, Request, sample_token


@dataclasses.dataclass
class _Slot:
    """Book-keeping for one live request occupying one batch row."""
    req: Request
    index: int                    # fill level: next cache write position
    last_tok: int
    generated: List[int]
    admitted_tick: int
    pages: List[int] = dataclasses.field(default_factory=list)
    reserve_left: int = 0         # growth pages still drawable from pool
    # queued prompt chunks: (inputs, start, last-logit column or None)
    chunks: List[tuple] = dataclasses.field(default_factory=list)

    @property
    def key(self):
        return jax.random.PRNGKey(self.req.sampling.seed)


class PageAllocator:
    """Host-side free list over the global page pool.

    Page 0 is reserved as the trash page (parked-slot scratch writes and
    unallocated block-table entries), so capacity ``n_pages`` serves at
    most ``n_pages - 1`` live pages.  Pops lowest-id-first so allocation
    traces are deterministic and replayable.

    Admission control is *reservation*-based: a request only enters a slot
    when its worst-case page total (prompt + generation budget) fits in
    ``free - reserved``, and its not-yet-drawn tail is recorded in
    ``reserved``.  Pages are still *allocated* lazily (prompt pages at
    admission, decode pages one block at a time), so ``in_use``/
    ``peak_in_use`` track tokens actually held — but mid-decode growth can
    never exhaust the pool, and EOS-early retirement hands its unused
    reservation straight back."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError(f"page pool needs >= 2 pages (one is the "
                             f"reserved trash page), got {n_pages}")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, 0, -1))
        self.reserved = 0          # promised to live slots, not yet drawn
        self.peak_in_use = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.n_pages - 1 - len(self._free)

    def can_admit(self, total_pages: int) -> bool:
        return total_pages <= len(self._free) - self.reserved

    def alloc(self, n: int, from_reserve: int = 0) -> Optional[List[int]]:
        """n pages (releasing ``from_reserve`` of the caller's
        reservation), or None if the free list cannot satisfy it."""
        if n > len(self._free):
            return None
        self.reserved -= from_reserve
        pages = [self._free.pop() for _ in range(n)]
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pages

    def release(self, pages: List[int], from_reserve: int = 0) -> None:
        self.reserved -= from_reserve
        self._free.extend(sorted(pages, reverse=True))


def _paged_pool_bytes(cache) -> int:
    """Total at-rest bytes of every page-pool leaf in a cache tree."""
    if isinstance(cache, dict):
        if "table" in cache:
            return sum(int(leaf.nbytes) for leaf in
                       jax.tree_util.tree_leaves(cache["pages"]))
        return sum(_paged_pool_bytes(v) for v in cache.values())
    return 0


def _kv_resident_bytes(cache) -> int:
    """At-rest bytes of a contiguous cache's KV leaves (k/v + scales)."""
    if isinstance(cache, dict):
        if "k" in cache and "v" in cache:
            return sum(int(leaf.nbytes) for leaf in
                       jax.tree_util.tree_leaves(cache))
        return sum(_kv_resident_bytes(v) for v in cache.values())
    return 0


class Scheduler:
    """Continuous batching over a :class:`ServeEngine`.

    ``max_len`` is the per-slot cache width; a request needs
    ``prompt_width + max_new_tokens - 1 <= max_len`` positions.  With the
    fixed-width cache the decode state is created lazily on the first
    admission (the first prompt is tiled across all rows so the state
    tree — cache layout, enc-dec encoder buffer — comes straight from the
    model's own prefill).  Paged / chunked modes build a zeroed state via
    ``ModelAPI.init_decode_state`` instead and insert every prompt —
    including the first — through the same block-table write path."""

    def __init__(self, engine, n_slots: int = 8, max_len: int = 256,
                 page_size: int = 0, n_pages: Optional[int] = None,
                 prefill_chunk: int = 0):
        self.engine = engine
        self.n_slots = n_slots
        self.max_len = max_len
        cfg = engine.api.cfg
        if page_size and cfg.family == "ssm":
            import warnings
            warnings.warn("family 'ssm' has no KV cache to page; "
                          "page_size ignored", stacklevel=3)
            page_size = 0
        if cfg.n_experts:
            from ..models.moe import GROUPED_IMPL
            if GROUPED_IMPL["impl"] == "capacity":
                import warnings
                warnings.warn(
                    "continuous batching with capacity-dropping MoE "
                    "dispatch: parked slots' dummy tokens compete for "
                    "expert capacity, so live requests may diverge from "
                    "one-shot generate(); use GROUPED_IMPL['impl']="
                    "'ragged' for exact parity", stacklevel=3)
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk
        self.paged = page_size > 0
        if self.paged:
            self.nb = -(-max_len // page_size)
            self.total_len = self.nb * page_size
            self.allocator = PageAllocator(n_pages or
                                           1 + n_slots * self.nb)
            self.tables = np.zeros((n_slots, self.nb), np.int32)
        else:
            self.nb = 0
            self.total_len = max_len
            self.allocator = None
            self.tables = None
        self._tables_dirty = False
        # paged / chunked prompts go through the zero-state insertion path
        self._insert_path = self.paged or prefill_chunk > 0
        self.state: Any = None
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.waiting: List[Request] = []
        self.tick = 0
        self.results: Dict[int, GenerationResult] = {}
        # speculative-decode accounting (acceptance rate, bench rows)
        self.spec_stats: Dict[str, int] = {
            "rounds": 0, "drafted": 0, "accepted_drafts": 0, "emitted": 0}

    # ---- submission ------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.uid in self.results or \
                any(r.uid == req.uid for r in self.waiting) or \
                any(s is not None and s.req.uid == req.uid
                    for s in self.slots):
            raise ValueError(f"duplicate request uid {req.uid}")
        need = self.engine.prompt_width(req.inputs) + \
            req.sampling.max_new_tokens - 1
        if need > self.max_len:
            raise ValueError(
                f"request {req.uid} needs {need} cache positions, "
                f"scheduler max_len is {self.max_len}")
        if self.paged:
            pages = -(-need // self.page_size)
            if pages > self.allocator.n_pages - 1:
                raise ValueError(
                    f"request {req.uid} needs {pages} pages, pool capacity "
                    f"is {self.allocator.n_pages - 1} live pages")
        self.waiting.append(req)
        self.waiting.sort(key=lambda r: r.arrival)

    # ---- admission -------------------------------------------------------
    def _first_token(self, slot: _Slot, logits_row) -> None:
        sp = slot.req.sampling
        key = jax.random.fold_in(slot.key, 0) if sp.temperature > 0 else None
        tok = int(sample_token(logits_row, sp, key))
        slot.generated.append(tok)
        slot.last_tok = tok

    def _flush_tables(self) -> None:
        if self._tables_dirty:
            self.state = self.engine.set_tables(self.state, self.tables)
            self._tables_dirty = False

    def _plan_chunks(self, req: Request) -> List[tuple]:
        """Split a prompt into (inputs, start, last-col) insertion chunks.

        The vision prefix / encoder frames ride the first chunk (which
        therefore starts at cache position 0); later chunks carry tokens
        only and start at their cache position (vision offset included).
        Only the final chunk reports a logits column (the last *real*
        token — the final chunk is zero-padded to the chunk width so every
        chunk compiles to one shape).

        Recurrent-state families (ssm, hybrid) always insert monolithic:
        their state has no fill-level masking, so padded tokens would
        pollute it, and the rwkv/mamba chunked scans are only
        FP-*approximately* invariant to the chunk decomposition — not the
        bit-exact parity this scheduler guarantees."""
        inputs = req.inputs
        toks = np.asarray(inputs["tokens"])
        p = toks.shape[1]
        cw = self.prefill_chunk
        cfg = self.engine.api.cfg
        tv = cfg.vision_tokens if cfg.family == "vlm" else 0
        if cw <= 0 or p <= cw or cfg.family in ("ssm", "hybrid"):
            return [(inputs, 0, None)]
        chunks = []
        n_c = -(-p // cw)
        for c in range(n_c):
            lo, hi = c * cw, min((c + 1) * cw, p)
            w = hi - lo
            ct = toks[:, lo:hi]
            last = c == n_c - 1
            if last and w < cw:
                # pad to the chunk width for one compile shape, but never
                # past the slot's cache extent: an overflowing write would
                # clamp (contiguous) or alias in-page offsets (paged) onto
                # real prompt K/V
                padded = min(cw, self.total_len - (tv + lo))
                ct = np.pad(ct, ((0, 0), (0, padded - w)))
            b = {"tokens": jnp.asarray(ct)}
            if c == 0:
                for extra in ("vision_embeds", "frames"):
                    if extra in inputs:
                        b[extra] = inputs[extra]
            start = 0 if c == 0 else tv + lo
            col = ((tv if c == 0 else 0) + w - 1) if last else None
            chunks.append((b, start, col))
        return chunks

    def _admit_into(self, i: int, req: Request) -> bool:
        """Place ``req`` into free slot ``i``; False if the page pool
        cannot cover its prompt yet (request stays queued)."""
        inputs = req.inputs
        pw = self.engine.prompt_width(inputs)
        if self._insert_path:
            if self.state is None:
                self.state = self.engine.init_decode_state(
                    inputs, self.n_slots, self.max_len,
                    page_size=self.page_size,
                    n_pages=self.allocator.n_pages if self.paged else None)
            if "frames" in inputs and \
                    inputs["frames"].shape[1] != \
                    self.state["enc_out"].shape[1]:
                raise ValueError(
                    "enc-dec slot insertion needs the same encoder length "
                    f"as the live batch: {inputs['frames'].shape[1]} != "
                    f"{self.state['enc_out'].shape[1]}")
            reserve = 0
            if self.paged:
                need = pw + req.sampling.max_new_tokens - 1
                total = -(-need // self.page_size)
                prompt_pages = min(-(-pw // self.page_size), total)
                if not self.allocator.can_admit(total):
                    return False
                pages = self.allocator.alloc(prompt_pages)
                reserve = total - prompt_pages
                self.allocator.reserved += reserve
                self.tables[i, :len(pages)] = pages
                self._tables_dirty = True
            else:
                pages = []
            self.slots[i] = _Slot(req=req, index=pw, last_tok=0,
                                  generated=[], admitted_tick=self.tick,
                                  pages=pages, reserve_left=reserve,
                                  chunks=self._plan_chunks(req))
            return True
        # ---- legacy fixed-width path (monolithic prefill) ---------------
        if self.state is None:
            # Lazy state init: prefill the first prompt ONCE at full cache
            # width, then broadcast its state rows across all slots (rows
            # are identical by construction, so this matches an n_slots-way
            # tiled prefill at 1/n_slots the compute).
            extra = self.max_len - pw
            logits, sub = self.engine.prefill(inputs, extra_slots=extra,
                                              place_state=False)
            state = dict(sub)
            state["cache"] = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(
                    x, (x.shape[0], self.n_slots, *x.shape[2:])),
                sub["cache"])
            if "enc_out" in sub:
                state["enc_out"] = jnp.broadcast_to(
                    sub["enc_out"], (self.n_slots, *sub["enc_out"].shape[1:]))
            self.state = self.engine._shard_state(state, self.n_slots)
            row = logits[0]
        else:
            logits, self.state = self.engine.prefill_at(inputs, self.state,
                                                        jnp.asarray(i))
            row = logits[0]
        slot = _Slot(req=req, index=pw, last_tok=0, generated=[],
                     admitted_tick=self.tick)
        self._first_token(slot, row)
        self.slots[i] = slot
        self._maybe_retire(i)
        return True

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if not self.waiting or self.waiting[0].arrival > self.tick:
                return
            if self.slots[i] is None:
                if not self._admit_into(i, self.waiting[0]):
                    return          # head-of-line blocked on free pages
                self.waiting.pop(0)

    # ---- chunked / paged prompt insertion --------------------------------
    def _advance_prefills(self) -> None:
        """One prompt chunk per mid-prefill slot per tick; the final chunk
        samples the request's first token (as monolithic admission does)."""
        for i, s in enumerate(self.slots):
            if s is None or not s.chunks:
                continue
            self._flush_tables()
            batch, start, col = s.chunks.pop(0)
            logits, self.state = self.engine.prefill_chunk_at(
                batch, self.state, i, start)
            if not s.chunks:
                self._first_token(s, logits[0, -1 if col is None else col])
                self._maybe_retire(i)

    # ---- paged growth ----------------------------------------------------
    def _grow_pages(self, live: List[int], lookahead: int = 0) -> None:
        """Allocate pages for every slot whose upcoming writes cross block
        boundaries.  Plain decode advances one token per tick (at most one
        page per slot); a speculative round writes up to ``lookahead``
        positions past the fill level in one tick, so growth may claim
        several pages — all from the slot's admission-time reservation,
        because the round's draft depth is clamped to the slot's remaining
        token budget (the free list can never come up short here)."""
        for i in live:
            s = self.slots[i]
            blk_hi = (s.index + lookahead) // self.page_size
            while len(s.pages) <= blk_hi:
                blk = len(s.pages)
                page = self.allocator.alloc(1, from_reserve=1)
                assert page is not None and s.reserve_left > 0, \
                    f"reservation accounting broke for slot {i}"
                s.reserve_left -= 1
                s.pages += page
                self.tables[i, blk] = page[0]
                self._tables_dirty = True

    # ---- retirement ------------------------------------------------------
    def _maybe_retire(self, i: int) -> None:
        slot = self.slots[i]
        sp = slot.req.sampling
        stop = sp.eos_id is not None and slot.generated[-1] == sp.eos_id
        length = len(slot.generated) >= sp.max_new_tokens
        if stop or length:
            self.results[slot.req.uid] = GenerationResult(
                uid=slot.req.uid, tokens=list(slot.generated),
                finish_reason="stop" if stop else "length",
                prompt_len=slot.req.inputs["tokens"].shape[1],
                admitted_tick=slot.admitted_tick,
                finished_tick=self.tick)
            if self.paged and (slot.pages or slot.reserve_left):
                self.allocator.release(slot.pages,
                                       from_reserve=slot.reserve_left)
                self.tables[i, :] = 0
                self._tables_dirty = True
            self.slots[i] = None

    # ---- speculative tick ------------------------------------------------
    def _spec_tick(self, live: List[int]) -> bool:
        """One draft/verify round over the live greedy slots.

        Draft depth is clamped round-wide to the tightest slot's remaining
        token budget minus one (each slot emits at least one verify-chosen
        token), so every cache write — γ draft steps at ``index..index+γ-1``
        plus the (γ+1)-wide verify at ``index`` — stays inside each slot's
        admission-time page reservation.  Rejected draft K/V needs no
        rollback: it sits above the accepted fill level, masked by
        ``kv_len``, and the next round's verify rewrites it at full
        precision before it can ever be unmasked — so the page pool drains
        leak-free.  Returns False (caller runs the plain tick) when no
        draft depth fits."""
        from .autotune.speculative import greedy_verify
        eng = self.engine
        g = min(eng.draft_gamma,
                min(self.slots[i].req.sampling.max_new_tokens
                    - len(self.slots[i].generated) for i in live) - 1)
        if g < 1:
            return False
        if self.paged:
            self._grow_pages(live, lookahead=g)
        self._flush_tables()
        toks = np.zeros((self.n_slots, 1), np.int32)
        # parked rows write masked scratch at the last position (paged:
        # the trash page), exactly like the plain tick
        idx = np.full((self.n_slots,), self.total_len - 1, np.int32)
        for i in live:
            toks[i, 0] = self.slots[i].last_tok
            idx[i] = self.slots[i].index
        idx_j = jnp.asarray(idx)
        cur, drafts, state = jnp.asarray(toks), [], self.state
        for j in range(g):
            lg, state = eng.draft_decode(cur, state, idx_j + j)
            cur = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
            drafts.append(cur)
        vtoks = jnp.concatenate([jnp.asarray(toks)] + drafts, axis=1)
        vlogits, self.state = eng.verify(vtoks, state, idx_j)
        accepted, n_draft = greedy_verify(np.asarray(vtoks[:, 1:]),
                                          np.asarray(vlogits))
        self.spec_stats["rounds"] += 1
        for i in live:
            slot = self.slots[i]
            sp = slot.req.sampling
            self.spec_stats["drafted"] += g
            self.spec_stats["accepted_drafts"] += int(n_draft[i])
            for t in accepted[i]:
                slot.generated.append(int(t))
                slot.last_tok = int(t)
                slot.index += 1
                self.spec_stats["emitted"] += 1
                if (sp.eos_id is not None and int(t) == sp.eos_id) or \
                        len(slot.generated) >= sp.max_new_tokens:
                    break                 # discard the rest of the round
            self._maybe_retire(i)
        return True

    # ---- one tick --------------------------------------------------------
    def step(self) -> None:
        """Admit what has arrived, advance mid-prefill slots one chunk,
        then advance every decoding slot one token."""
        self._admit()
        if self._insert_path:
            self._advance_prefills()
        live = [i for i, s in enumerate(self.slots)
                if s is not None and not s.chunks]
        if live and self.engine.speculate_planes and \
                all(self.slots[i].req.sampling.temperature == 0
                    for i in live):
            if self._spec_tick(live):
                self.tick += 1
                return
        if live:
            if self.paged:
                self._grow_pages(live)
            self._flush_tables()
            toks = np.zeros((self.n_slots, 1), np.int32)
            # parked rows write their (ignored) K/V at the last position —
            # with a paged cache that position routes to the trash page —
            # where it stays masked by the row's fill level
            idx = np.full((self.n_slots,), self.total_len - 1, np.int32)
            for i in live:
                toks[i, 0] = self.slots[i].last_tok
                idx[i] = self.slots[i].index
            logits, self.state = self.engine.decode(
                jnp.asarray(toks), self.state, jnp.asarray(idx))
            lg = np.asarray(logits)       # one host transfer per tick
            for i in live:
                slot = self.slots[i]
                sp = slot.req.sampling
                if sp.temperature > 0:
                    key = jax.random.fold_in(slot.key, len(slot.generated))
                    tok = int(sample_token(jnp.asarray(lg[i]), sp, key))
                else:
                    tok = int(lg[i].argmax())
                slot.generated.append(tok)
                slot.last_tok = tok
                slot.index += 1
                self._maybe_retire(i)
        self.tick += 1

    # ---- reporting -------------------------------------------------------
    def compile_footprint(self, prompt_widths=None) -> List[Any]:
        """Static census of every jit signature this scheduler's workload
        compiles (``analysis.footprint``) — run it *before* serving to
        catch a recompile blowup as a lint failure, not a latency
        mystery.  ``prompt_widths`` defaults to the submitted requests'."""
        from ..analysis.footprint import scheduler_footprint
        return scheduler_footprint(self, prompt_widths)

    def cache_report(self) -> Dict[str, Any]:
        """Resident-cache accounting (the paged-vs-fixed-width headline).

        ``bytes_in_use_peak`` charges each allocated page its full at-rest
        footprint across every layer; ``fixed_equiv_bytes`` is what the
        same workload would hold resident as ``n_slots`` fixed
        ``max_len``-wide rows."""
        if self.state is None:
            return {"paged": self.paged}
        if not self.paged:
            return {"paged": False,
                    "resident_bytes": _kv_resident_bytes(
                        self.state["cache"])}
        pool_bytes = _paged_pool_bytes(self.state["cache"])
        cap = self.allocator.n_pages
        page_bytes = pool_bytes // cap
        return {
            "paged": True,
            "page_size": self.page_size,
            "pool_capacity_pages": cap,
            "pages_in_use": self.allocator.in_use,
            "peak_pages_in_use": self.allocator.peak_in_use,
            "page_bytes": page_bytes,
            "bytes_in_use_peak": self.allocator.peak_in_use * page_bytes,
            "fixed_equiv_bytes": page_bytes * self.n_slots *
            self.max_len // self.page_size,
        }

    # ---- drive to completion --------------------------------------------
    def run(self, requests: List[Request]) -> List[GenerationResult]:
        """Submit ``requests`` and tick until all have finished; results
        come back in the order the requests were given."""
        for r in requests:
            self.submit(r)
        while self.waiting or any(s is not None for s in self.slots):
            self.step()
        return [self.results[r.uid] for r in requests]
