"""Serve-time precision autotuning + self-speculative decoding.

Three layers over the PR 5 bit-plane serving stack:

* :mod:`sensitivity` — per-WB-block plane sensitivity scores from
  calibration activations, computed on the already-deployed bitplane
  tree (no f32 retrain pass);
* :mod:`allocate` — greedy marginal-utility search assigning per-block
  bit-widths under a ``weight_stream_bytes`` budget, emitting a valid
  re-packed tree (BP1-BP3 + AT1) gated by a prefill-logit check;
* :mod:`speculative` — the truncated-plane read of the *same* deployed
  leaves as a free draft model (``ServeEngine(..., speculate_planes=k)``),
  with greedy verify token-identical to non-speculative decode (AT2).
"""
from .allocate import Allocation, autotune_params, greedy_allocate, \
    quality_gate
from .sensitivity import calibrate_activations, leaf_plane_sensitivity, \
    sensitivity_tree, tag_bitplane_leaves
from .speculative import greedy_verify, make_draft_params

__all__ = [
    "Allocation", "autotune_params", "greedy_allocate", "quality_gate",
    "calibrate_activations", "leaf_plane_sensitivity", "sensitivity_tree",
    "tag_bitplane_leaves", "greedy_verify", "make_draft_params",
]
