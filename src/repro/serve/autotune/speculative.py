"""Self-speculative decoding via bitplane truncation.

The plane-sliced serving layout makes a draft model free: truncating
every block's mask LUT to its top-k live planes
(:func:`repro.kernels.ops.truncate_mask_topk`) yields a coarser read of
the *same* deployed payload — no second weight copy, no retrain, and
``bitplane_matmul`` consumes the truncated LUT unchanged.  The draft
tree is a pure view (planes/sign/scale shared, AT2), so building it
costs one small mask recompute per leaf.

Protocol per round (greedy sampling):

1. draft γ tokens with the truncated tree, one decode step each,
   writing draft K/V at ``index .. index+γ-1``;
2. one batched verify forward with the FULL tree over
   ``[last_tok, d_1 .. d_γ]`` (width γ+1) at the same offsets — it
   overwrites every draft K/V entry with full-precision values and
   returns per-position logits;
3. accept the longest matching prefix (``d_j == argmax(l_{j-1})``) plus
   one correction/bonus token from the first mismatching (or final)
   verify logits.

Every cache position below the accepted fill level was therefore last
written by a verify pass, which is what makes greedy speculative decode
token-identical to non-speculative decode; rejected positions sit above
the fill level, masked by ``kv_len``, and are rewritten by the next
round's verify before ever being unmasked — no rollback bookkeeping and
no page-pool residue.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Tuple

import jax
import numpy as np

from ...kernels.ops import truncate_mask_topk
from ..deploy import BitplaneServingWeight


def _is_bp(x) -> bool:
    return isinstance(x, BitplaneServingWeight)


def make_draft_params(params: Any, k: int) -> Any:
    """Truncated-mask view of a deployed tree: the free draft model.

    Payload tensors are shared with the deployed tree (zero-copy); only
    the mask LUTs are recomputed.  The result intentionally violates BP2
    (low planes are zeroed), so it must NOT go through deploy-time
    validation — the AT2 contract (:func:`repro.analysis.contracts.
    validate_draft_truncation`) is its check instead."""
    if k < 1:
        raise ValueError(f"speculate_planes must be >= 1, got {k}")
    n_bp = 0

    def conv(x):
        nonlocal n_bp
        if _is_bp(x):
            n_bp += 1
            return dataclasses.replace(x, mask=truncate_mask_topk(x.mask, k))
        return x
    out = jax.tree_util.tree_map(conv, params, is_leaf=_is_bp)
    if n_bp == 0:
        raise ValueError(
            "speculative decoding needs a plane-sliced tree (no "
            "BitplaneServingWeight leaves found); deploy with "
            "layout='bitplane'")
    return out


def greedy_verify(draft_tokens: np.ndarray, verify_logits: np.ndarray
                  ) -> Tuple[List[np.ndarray], np.ndarray]:
    """Host-side greedy acceptance for one speculative round.

    ``draft_tokens`` (B, γ) int, ``verify_logits`` (B, γ+1, V) from the
    full-mask verify forward.  Per row: accept drafts while they match
    the verify argmax, then append the correction (first mismatch) or
    bonus (all matched) token.  Returns the per-row accepted token
    arrays (each length 1..γ+1) and the per-row count of accepted
    *draft* tokens (for acceptance-rate accounting)."""
    draft = np.asarray(draft_tokens)
    logits = np.asarray(verify_logits)
    b, gamma = draft.shape
    ref = np.argmax(logits, axis=-1)              # (B, γ+1)
    accepted: List[np.ndarray] = []
    n_draft = np.zeros((b,), dtype=np.int64)
    for r in range(b):
        toks = []
        for j in range(gamma):
            if int(draft[r, j]) == int(ref[r, j]):
                toks.append(int(draft[r, j]))
            else:
                toks.append(int(ref[r, j]))       # correction
                break
        else:
            toks.append(int(ref[r, gamma]))       # bonus
        n_draft[r] = len(toks) - 1       # last token is correction/bonus
        accepted.append(np.asarray(toks, dtype=np.int64))
    return accepted, n_draft
