"""Budgeted per-block bit allocation over the deployed bitplane tree.

Greedy marginal-utility search: every (leaf, block) starts at zero
planes and candidate increments — "give this block one more plane,
recovered top-down from its deployed occupancy" — are taken in order of
predicted-error reduction per streamed byte.  The cost model is the
PR 5 occupancy accounting itself (``bitplane_stream_bytes`` /
``weight_stream_bytes``): one live plane streams one wbr x wbc 1-bit
tile, a block's first plane also streams its sign tile, and the exact
per-leaf ceil-to-byte totals are recomputed as the sequence is taken so
the emitted tree respects the budget *exactly* under the same
accounting the AT1 contract re-checks.

Two properties the satellite property suite pins:

* the greedy sequence is deterministic and budget-independent, and a
  budget buys its longest affordable prefix — so a larger budget takes
  a superset of increments and predicted error is monotone
  non-increasing in the budget;
* the emitted occupancies re-pack through
  :func:`repro.serve.deploy.repack_bitplane_leaf`, whose output is
  prefix-monotone (BP2) by construction and bit-identical to the
  deployed tree wherever a block keeps its full occupancy.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Dict, Optional

import jax
import numpy as np

from ..deploy import (bitplane_stream_bytes, repack_bitplane_leaf,
                      weight_stream_bytes)
from .sensitivity import (_is_bp, _leaf_path_map, calibrate_activations,
                          sensitivity_tree)


@dataclasses.dataclass
class Allocation:
    """Result of a greedy budget search (plus the optional quality gate)."""
    params: Any                    # re-packed serving tree
    budget_bytes: int
    total_bytes: int               # weight_stream_bytes(params), <= budget
    predicted_error: float         # sum of scores of planes left dropped
    baseline_error: float          # error of the all-zero assignment
    occupancies: Dict[str, np.ndarray]   # path -> (stack..., GR, GC) ints
    steps_taken: int
    steps_available: int
    gate: Optional[dict] = None


def _leaf_bytes(leaf, live_planes: int, live_blocks: int) -> int:
    """bitplane_stream_bytes at a hypothetical occupancy (same math)."""
    wbr, wbc = leaf.spec.wb_rows, leaf.spec.wb_cols
    plane_bits = (live_planes + live_blocks) * wbr * wbc
    return int(-(-plane_bits // 8) + -(-int(np.asarray(leaf.mask).size) // 8)
               + int(leaf.scale.nbytes))


def greedy_allocate(params: Any, scores: Dict[str, np.ndarray],
                    budget_bytes: int) -> Allocation:
    """Assign per-block plane occupancies under ``budget_bytes``.

    ``scores`` comes from :func:`sensitivity_tree` (mask-aligned, one
    entry per bitplane leaf).  Raises if even the zero-plane tree (mask
    and scale LUTs plus all non-bitplane leaves) exceeds the budget."""
    leaves = _leaf_path_map(params)
    missing = sorted(set(leaves) - set(scores))
    if missing:
        raise ValueError(f"scores missing for leaves: {missing[:4]}")

    paths = sorted(leaves)
    # Exact byte bookkeeping: non-bitplane bytes are budget-invariant.
    nonbp = weight_stream_bytes(params) - sum(
        bitplane_stream_bytes(leaves[p]) for p in paths)
    leaf_state = {}                       # path -> [live_planes, live_blocks]
    total = nonbp
    for p in paths:
        leaf_state[p] = [0, 0]
        total += _leaf_bytes(leaves[p], 0, 0)
    if total > budget_bytes:
        raise ValueError(
            f"budget {budget_bytes} B infeasible: fixed overhead (mask + "
            f"scale LUTs + non-bitplane leaves) is {total} B")

    # Candidate increments, heap-ordered by error reduction per byte.
    # Within a block planes must be recovered top-down (t ascending), so
    # the heap holds each block's next increment only.
    occ_full: Dict[str, np.ndarray] = {}
    taken: Dict[str, np.ndarray] = {}
    heap = []
    steps_available = 0
    baseline_error = 0.0
    for li, p in enumerate(paths):
        leaf = leaves[p]
        s = np.asarray(scores[p], dtype=np.float64)
        if s.shape != tuple(leaf.mask.shape):
            raise ValueError(f"{p}: scores shape {s.shape} != mask "
                             f"{tuple(leaf.mask.shape)}")
        occ = np.asarray(leaf.mask).sum(axis=-3).astype(np.int64)
        occ_full[p] = occ
        taken[p] = np.zeros_like(occ)
        baseline_error += float(s.sum())
        wbr, wbc = leaf.spec.wb_rows, leaf.spec.wb_cols
        tile = wbr * wbc / 8.0
        s2 = s.reshape((-1,) + s.shape[-3:]) if occ.ndim > 2 else s[None]
        o2 = occ.reshape((-1,) + occ.shape[-2:]) if occ.ndim > 2 else occ[None]
        steps_available += int(o2.sum())
        for st in range(o2.shape[0]):
            for g in range(o2.shape[1]):
                for h in range(o2.shape[2]):
                    o = int(o2[st, g, h])
                    if o:
                        # s2 is (S, bits, GR, GC); increment t recovers
                        # plane o - t, and the first one also streams
                        # the block's sign tile.
                        gain = float(s2[st, o - 1, g, h])
                        heapq.heappush(heap, (-(gain / (2 * tile)),
                                              li, st, g, h, 1))

    err = baseline_error
    steps = 0
    while heap:
        neg, li, st, g, h, t = heapq.heappop(heap)
        p = paths[li]
        leaf = leaves[p]
        tk = taken[p].reshape((-1,) + taken[p].shape[-2:])
        o = int(occ_full[p].reshape(tk.shape)[st, g, h])
        lp, lb = leaf_state[p]
        new_lb = lb + (1 if t == 1 else 0)
        new_total = total - _leaf_bytes(leaf, lp, lb) \
            + _leaf_bytes(leaf, lp + 1, new_lb)
        if new_total > budget_bytes:
            break                       # longest affordable prefix
        total = new_total
        leaf_state[p] = [lp + 1, new_lb]
        tk[st, g, h] = t
        s2 = np.asarray(scores[p], dtype=np.float64)
        s2 = s2.reshape((-1,) + s2.shape[-3:])
        err -= float(s2[st, o - t, g, h])
        steps += 1
        if t < o:
            tile = leaf.spec.wb_rows * leaf.spec.wb_cols / 8.0
            gain = float(s2[st, o - t - 1, g, h])
            heapq.heappush(heap, (-(gain / tile), li, st, g, h, t + 1))

    new_leaves = {p: repack_bitplane_leaf(leaves[p], taken[p])
                  for p in paths}

    def conv(path, x):
        if _is_bp(x):
            return new_leaves[jax.tree_util.keystr(path)]
        return x
    out = jax.tree_util.tree_map_with_path(conv, params, is_leaf=_is_bp)

    from ...analysis.contracts import validate_allocation, \
        validate_serving_tree
    bad = [f for f in validate_serving_tree(out) if f.severity == "error"]
    bad += [f for f in validate_allocation(out, budget_bytes)
            if f.severity == "error"]
    if bad:
        raise ValueError("allocation produced a contract-violating tree:\n"
                         + "\n".join(f.format() for f in bad[:8]))
    return Allocation(params=out, budget_bytes=int(budget_bytes),
                      total_bytes=weight_stream_bytes(out),
                      predicted_error=err, baseline_error=baseline_error,
                      occupancies=taken, steps_taken=steps,
                      steps_available=steps_available)


def quality_gate(api, deployed: Any, tuned: Any, batch: Dict[str, Any], *,
                 backend: str = "dense",
                 min_top1_agreement: float = 1.0) -> dict:
    """Prefill-logit check of the tuned tree against the full deployment.

    Both trees run the same jitted prefill; the gate compares last-token
    logits (top-1 agreement across the calibration batch plus the max
    absolute logit drift).  Returns the metrics dict with ``ok`` set."""
    from ...models.common import matmul_backend

    def last_logits(tree):
        with matmul_backend(backend):
            return jax.jit(lambda p: api.prefill(p, batch)[0])(tree)
    full = np.asarray(last_logits(deployed), dtype=np.float64)
    test = np.asarray(last_logits(tuned), dtype=np.float64)
    agree = float(np.mean(np.argmax(full, -1) == np.argmax(test, -1)))
    return {"top1_agreement": agree,
            "max_abs_logit_diff": float(np.max(np.abs(full - test))),
            "min_top1_agreement": float(min_top1_agreement),
            "ok": agree >= min_top1_agreement}


def autotune_params(api, params: Any, budget_bytes: int, *,
                    batch: Optional[Dict[str, Any]] = None,
                    backend: str = "dense",
                    min_top1_agreement: float = 0.0,
                    require_gate: bool = False) -> Allocation:
    """One-call orchestration: calibrate -> score -> allocate -> gate.

    ``batch`` (a prefill feed dict) drives both the activation
    calibration and the quality gate; omit it for weight-only scores and
    no gate.  ``require_gate`` raises if the gate fails rather than just
    recording it."""
    act2 = calibrate_activations(api, params, batch) if batch else None
    scores = sensitivity_tree(params, act2)
    alloc = greedy_allocate(params, scores, budget_bytes)
    if batch is not None:
        alloc.gate = quality_gate(api, params, alloc.params, batch,
                                  backend=backend,
                                  min_top1_agreement=min_top1_agreement)
        if require_gate and not alloc.gate["ok"]:
            raise ValueError(f"autotune quality gate failed: {alloc.gate}")
    return alloc
