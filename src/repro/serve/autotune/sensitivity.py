"""Plane-drop sensitivity calibration on the deployed bitplane tree.

Dropping plane ``b`` of block (g, h) perturbs every covered weight
element by ``2^b * scale[g, h] * plane_b[k, n]``; under a diagonal
activation model (cross moments ``E[x_k x_k']`` neglected) the induced
output MSE is ``sum_{k, n in block} E[x_k^2] * (2^b * scale *
plane_b[k, n])^2``.  That is cheap to evaluate exactly from the packed
planes themselves — the scores come from the *deployed* tree, never a
f32 retrain pass — and only needs the per-input-feature second moments
``E[x_k^2]`` of whatever activations feed each leaf.

Those moments come from one eager calibration forward: the config is
rebuilt with ``scan_layers=False`` so ``scan_or_loop`` unrolls into a
concrete per-layer python loop, each sliced bit-plane leaf reaches
``qmatmul`` as an eager value carrying its static ``tag``, and the
:func:`repro.models.common.record_qmatmul_inputs` context captures the
moments keyed by tag in layer order.  Leaves the eager pass cannot
attribute (consumed through ragged/grouped expert paths or re-traced
inner scans) fall back to weight-only scores (``E[x_k^2] = 1``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import numpy as np

from ...models import common as mcommon
from ..deploy import BitplaneServingWeight


def _is_bp(x) -> bool:
    return isinstance(x, BitplaneServingWeight)


def _leaf_path_map(params) -> Dict[str, BitplaneServingWeight]:
    """Deployed bitplane leaves keyed by their keystr tree path."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params, is_leaf=_is_bp)
    return {jax.tree_util.keystr(path): leaf
            for path, leaf in flat if _is_bp(leaf)}


def tag_bitplane_leaves(params: Any) -> Any:
    """Copy of the tree with every bitplane leaf's ``tag`` set to its path.

    The tag is a *static* dataclass field, so it survives the per-layer
    ``tree_map`` slicing inside ``scan_or_loop`` — which is what lets the
    qmatmul recorder attribute activations back to stacked leaves."""
    def conv(path, x):
        if _is_bp(x):
            return dataclasses.replace(x, tag=jax.tree_util.keystr(path))
        return x
    return jax.tree_util.tree_map_with_path(conv, params, is_leaf=_is_bp)


def calibrate_activations(api, params: Any, batch: Dict[str, Any]
                          ) -> Dict[str, Optional[np.ndarray]]:
    """One eager prefill over ``batch``; per-leaf activation moments.

    Returns ``{path: (stack..., K) float64 array or None}`` for every
    bitplane leaf — ``None`` marks the weight-only fallback (the leaf was
    consumed a different number of times than its stack size, so the
    layer-order restack would be wrong)."""
    from ...models.api import build
    cfg = dataclasses.replace(api.cfg, scan_layers=False)
    eager_api = build(cfg)
    tagged = tag_bitplane_leaves(params)
    with mcommon.matmul_backend("dense"):
        with mcommon.record_qmatmul_inputs() as store:
            eager_api.prefill(tagged, batch)
    out: Dict[str, Optional[np.ndarray]] = {}
    for path, leaf in _leaf_path_map(tagged).items():
        stack_dims = tuple(leaf.shape[:-2])
        stack = int(np.prod(stack_dims, dtype=np.int64)) if stack_dims else 1
        recs = store.get(path, [])
        if len(recs) != stack:
            out[path] = None
            continue
        arr = np.stack([np.asarray(r, dtype=np.float64) for r in recs])
        out[path] = arr.reshape(stack_dims + (arr.shape[-1],))
    return out


def leaf_plane_sensitivity(leaf: BitplaneServingWeight,
                           act2: Optional[np.ndarray] = None) -> np.ndarray:
    """Scores shaped exactly like ``leaf.mask``: (stack..., bits, GR, GC).

    ``scores[..., b, g, h]`` is the predicted output-MSE contribution of
    dropping plane ``b`` from block (g, h); dead planes score zero.
    ``act2`` is the (stack..., K) activation second-moment array from
    :func:`calibrate_activations` (``None`` -> weight-only, all ones)."""
    from ...kernels.ref import unpack_bits
    wbr, wbc = leaf.spec.wb_rows, leaf.spec.wb_cols
    mask = np.asarray(leaf.mask, dtype=np.float64)
    gr, gc = mask.shape[-2], mask.shape[-1]
    kp, np_ = gr * wbr, gc * wbc
    planes = np.asarray(unpack_bits(leaf.planes),
                        dtype=np.float64)[..., :kp, :np_]
    k_true = leaf.shape[-2]
    stack_dims = tuple(leaf.shape[:-2])
    a = np.ones(stack_dims + (k_true,), dtype=np.float64) if act2 is None \
        else np.broadcast_to(np.asarray(act2, dtype=np.float64),
                             stack_dims + (k_true,))
    a_pad = np.zeros(stack_dims + (kp,), dtype=np.float64)
    a_pad[..., :k_true] = a
    weighted = planes * a_pad[..., None, :, None]    # (..., bits, Kp, Np)
    blocks = weighted.reshape(weighted.shape[:-2] + (gr, wbr, gc, wbc))
    per_block = blocks.sum(axis=(-3, -1))            # (..., bits, GR, GC)
    bits = leaf.bits
    pw2 = (4.0 ** np.arange(bits)).reshape((bits, 1, 1))
    scale2 = np.asarray(leaf.scale, dtype=np.float64) ** 2
    return per_block * pw2 * scale2[..., None, :, :] * mask


def sensitivity_tree(params: Any,
                     act2_map: Optional[Dict[str, Optional[np.ndarray]]]
                     = None) -> Dict[str, np.ndarray]:
    """Sensitivity scores for every deployed bitplane leaf.

    Keys are keystr tree paths (1:1 with the deployed tree's bitplane
    leaves); each value is shaped like that leaf's mask LUT, so the
    score pytree is exactly mask-aligned.  ``act2_map`` is the output of
    :func:`calibrate_activations`; omitted entries use weight-only
    scores."""
    act2_map = act2_map or {}
    return {path: leaf_plane_sensitivity(leaf, act2_map.get(path))
            for path, leaf in _leaf_path_map(params).items()}
