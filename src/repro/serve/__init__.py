from .engine import ServeEngine
