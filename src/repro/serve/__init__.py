from .engine import ServeEngine
from .sampling import GenerationResult, Request, SamplingParams
from .scheduler import Scheduler

__all__ = ["ServeEngine", "Scheduler", "Request", "SamplingParams",
           "GenerationResult"]
# precision autotuning + self-speculative decoding live in
# repro.serve.autotune (imported lazily by the engine/CLIs to keep the
# base serve import light)
