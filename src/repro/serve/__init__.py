from .engine import ServeEngine
from .sampling import GenerationResult, Request, SamplingParams
from .scheduler import Scheduler

__all__ = ["ServeEngine", "Scheduler", "Request", "SamplingParams",
           "GenerationResult"]
