"""Batched serving engine: prefill + greedy decode over the ModelAPI.

Decode-shape inference is where BWQ's weight compression pays off on TPU
(HBM-bandwidth-bound); the engine optionally PACT-quantizes the KV cache
(beyond-paper, DESIGN.md §6) to push the same idea onto activations.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..core.pact import quantize_signed
from ..models.api import ModelAPI


@dataclasses.dataclass
class ServeEngine:
    api: ModelAPI
    params: Any
    kv_quant_bits: int = 32       # <32 enables KV-cache quantization

    def __post_init__(self):
        self._prefill = jax.jit(self.api.prefill,
                                static_argnames=("extra_slots",))
        self._decode = jax.jit(self.api.decode_step)

    def _maybe_quant_cache(self, state):
        if self.kv_quant_bits >= 32:
            return state
        def q(x):
            if isinstance(x, jnp.ndarray) and x.ndim >= 4:
                return quantize_signed(x, self.kv_quant_bits)
            return x
        return jax.tree_util.tree_map(q, state)

    def generate(self, batch: Dict[str, jnp.ndarray], max_new: int = 16,
                 greedy: bool = True, key=None) -> jnp.ndarray:
        """batch: prompt inputs per the model family. Returns (B, max_new)."""
        # round headroom up to limit recompiles across max_new values
        slots = -(-max_new // 64) * 64
        logits, state = self._prefill(self.params, batch, extra_slots=slots)
        state = self._maybe_quant_cache(state)
        prompt_len = batch["tokens"].shape[1]
        if self.api.cfg.family == "vlm":
            prompt_len += self.api.cfg.vision_tokens
        b = batch["tokens"].shape[0]
        outs: List[jnp.ndarray] = []
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        index = jnp.asarray(prompt_len, jnp.int32)
        for i in range(max_new):
            outs.append(tok[:, 0])
            logits, state = self._decode(self.params, tok, state, index)
            state = self._maybe_quant_cache(state)
            if greedy or key is None:
                tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits)[:, None].astype(
                    jnp.int32)
            index = index + 1
        return jnp.stack(outs, axis=1)
