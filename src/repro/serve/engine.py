"""Serving engine: jitted, mesh-aware prefill / decode over the ModelAPI.

Decode-shape inference is where BWQ's weight compression pays off on TPU
(HBM-bandwidth-bound).  The engine extends the same idea to activations
with a *quantized-at-rest* KV cache: ``kv_quant_bits`` of 8 or 4 rebuilds
the model config so the cache itself stores int8 / nibble-packed int4
entries plus per-token scales (models.attention) — each written slot is
rounded exactly once and dequantized in-graph per attention call.  This
replaces the old per-step whole-tree re-quantization, which both re-rounded
already-quantized entries every step (compounding error per token) and
burned O(cache) requant work per decoded token.

When a ``dist.sharding`` mesh is active at construction, parameters are
placed by ``param_pspecs`` and prompt/state tensors by ``batch_pspecs`` /
``cache_pspecs``, so prefill and decode run sharded (batch on the data
axes, KV heads on the model axis) with no API change.  Under
``padded_sharding`` (default) a dim the mesh does not divide is
zero-padded to the next multiple at placement and sliced back to its
true shape inside every jitted entry point — non-dividing vocab /
kv-head dims shard instead of replicating (see ``dist.sharding``).

``backend`` selects how deployed (ServingWeight / BitplaneServingWeight)
matmuls execute inside the jitted prefill/decode: ``dense`` dequantizes
each leaf in-graph and runs plain dots; ``pallas`` streams the deployed
representation through its Pallas kernel (interpret mode auto-detected
off-TPU); ``ref`` is the pure-jnp kernel oracle; ``bitplane`` runs the
paper's plane-sliced precision-aware mapping (deploy with
``to_serving_params(..., layout="bitplane")``) so per-step weight bytes
track each block's live bit count.  The flag is applied as a
trace-time ``models.common.matmul_backend`` context around every jitted
entry point, so the whole serving program is built for one backend and
A/B comparisons (benchmarks/serve_bench.py --backend) are apples-to-apples.

``attn_backend`` does the same for the decode-attention read side:
``gather`` re-materializes each slot's contiguous KV view and
dequantizes in-graph (legacy); ``fused`` runs the Pallas paged-attention
kernel over the stored (quantized) cache — block-table walk and KV
dequant happen inside the kernel, so the decode program never holds a
full-width or f32 KV tensor (graph_lint's kv-* census pins this);
``ref`` is that kernel's jnp oracle.  Applied as a trace-time
``models.attention.paged_attn_backend`` context alongside the matmul
backend.

Two call surfaces:
  * ``generate(batch, max_new)`` — one-shot static-batch decoding (legacy).
  * ``serve(requests)`` — request-level continuous batching through
    :class:`repro.serve.scheduler.Scheduler`; ``page_size`` /
    ``prefill_chunk`` engine fields (or per-call overrides) select the
    paged block-table KV cache and chunked prompt insertion — both
    token-identical to the contiguous monolithic path.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

# Decode donates its state (double-buffering a multi-GB KV cache per tick
# is the thing the graph lint forbids); platforms that cannot honor the
# donation (CPU tests) fall back to copying and would warn every call.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")

from ..dist.sharding import (batch_pspecs, cache_pspecs, get_mesh,
                             pad_leaf, param_pspecs, unpad_leaf, use_mesh)
from ..models.api import ModelAPI
from ..models.attention import PAGED_ATTN_BACKENDS, paged_attn_backend
from ..models.common import MATMUL_BACKENDS, matmul_backend
from .sampling import SamplingParams, sample_token


def _roundup64(n: int) -> int:
    # round headroom up to limit recompiles across max_new values
    return -(-n // 64) * 64


@dataclasses.dataclass
class ServeEngine:
    api: ModelAPI
    params: Any
    kv_quant_bits: int = 32       # 8 / 4 select the quantized-at-rest cache
    backend: str = "dense"        # 'dense' | 'pallas' | 'ref' matmul exec
    attn_backend: str = "gather"  # 'gather' | 'fused' | 'ref' decode attn
    page_size: int = 0            # >0: paged KV cache (tokens per page)
    n_pages: Optional[int] = None  # page-pool capacity (None = worst case)
    prefill_chunk: int = 0        # >0: insert prompts in chunks this wide
    overcommit: float = 1.0       # >1: admit past capacity, park victims
    prefix_cache: bool = False    # share full prompt pages by content hash
    donate_state: bool = True     # donate decode state (no double-buffer)
    padded_sharding: bool = True  # pad-place params on non-dividing axes
    validate: bool = True         # contract-check deployed leaves on build
    speculate_planes: int = 0     # >0: self-speculative decode, top-k draft
    draft_gamma: int = 4          # draft tokens proposed per round

    def __post_init__(self):
        cfg = self.api.cfg
        if self.backend not in MATMUL_BACKENDS:
            raise ValueError(f"backend must be one of {MATMUL_BACKENDS}, "
                             f"got {self.backend!r}")
        if self.attn_backend not in PAGED_ATTN_BACKENDS:
            raise ValueError(
                f"attn_backend must be one of {PAGED_ATTN_BACKENDS}, "
                f"got {self.attn_backend!r}")
        if self.backend != "dense" and not self._has_packed_weights():
            hint = ", layout='bitplane'" if self.backend == "bitplane" else ""
            warnings.warn(
                f"backend={self.backend!r} only accelerates deployed packed "
                f"weights (serve.deploy.to_serving_params(...{hint})); this "
                f"param tree has none, so execution is identical to 'dense'",
                stacklevel=2)
        if self.backend == "bitplane":
            from ..analysis.graph_lint import fallback_leaf_paths
            stale = fallback_leaf_paths(self.params, self.backend)
            if stale:
                warnings.warn(
                    f"backend='bitplane' executes only the plane-sliced "
                    f"layout; {len(stale)} packed ServingWeight leaves "
                    f"fall back to the in-graph dense dequant dot "
                    f"(deploy with layout='bitplane'): {stale[:4]}",
                    stacklevel=2)
        if self.validate:
            from ..analysis.contracts import validate_serving_tree
            bad = [f for f in validate_serving_tree(self.params)
                   if f.severity == "error"]
            if bad:
                raise ValueError(
                    "deployed param tree violates the serving contract:\n"
                    + "\n".join(f.format() for f in bad[:8]))
        if self.kv_quant_bits < 32:
            if self.kv_quant_bits not in (4, 8):
                raise ValueError(f"kv_quant_bits must be 4, 8 or >=32, "
                                 f"got {self.kv_quant_bits}")
            if cfg.family == "ssm":
                warnings.warn(
                    f"kv_quant_bits={self.kv_quant_bits} has no effect on "
                    f"family 'ssm': recurrent state has no KV cache and "
                    f"serves at full precision", stacklevel=2)
            cfg = dataclasses.replace(cfg,
                                      kv_cache_bits=self.kv_quant_bits)
            self.api = ModelAPI(cfg)
        self.mesh = get_mesh()
        self._pad_shapes = None   # true leaf shapes when params pad-placed
        self._prefill_j = self._jit(self.api.prefill,
                                    static_argnames=("extra_slots",))
        self._prefill_at_j = self._jit(self.api.prefill_at)
        self._prefill_chunk_j = self._jit(self.api.prefill_chunk_at)
        # decode_step(params, tokens, state, index): the state (arg 2) is
        # consumed and rebuilt every step — donate it so the cache updates
        # in place instead of double-buffering (graph lint enforces this)
        self._decode_j = self._jit(
            self.api.decode_step,
            **({"donate_argnums": (2,)} if self.donate_state else {}))
        self.draft_params = None
        self._verify_j = None
        if self.speculate_planes:
            if self.api.cfg.is_encdec or self.api.cfg.family in (
                    "ssm", "hybrid", "rwkv"):
                raise ValueError(
                    f"speculate_planes needs a purely positional KV cache "
                    f"(rejected drafts roll back by fill level); family "
                    f"{self.api.cfg.family!r} carries recurrent state")
            if self.draft_gamma < 1:
                raise ValueError(f"draft_gamma must be >= 1, "
                                 f"got {self.draft_gamma}")
            from .autotune.speculative import make_draft_params
            # Zero-copy top-k mask view; deliberately NOT BP2-validated
            # (it zeroes low planes) — AT2 is its contract instead.
            self.draft_params = make_draft_params(self.params,
                                                  self.speculate_planes)
            self._verify_j = self._jit(
                self.api.verify_step,
                **({"donate_argnums": (2,)} if self.donate_state else {}))
        if self.mesh is not None:
            self.params = self._place_params(self.params)
            if self.draft_params is not None:
                self.draft_params = self._place_params(self.draft_params)

    def _has_packed_weights(self) -> bool:
        """True if the tree holds leaves this backend can accelerate:
        ``bitplane`` executes only the plane-sliced layout (packed leaves
        fall back to dense); ``pallas``/``ref`` run either wire format."""
        from .deploy import BitplaneServingWeight, ServingWeight
        deployed = (ServingWeight, BitplaneServingWeight)
        want = (BitplaneServingWeight,) if self.backend == "bitplane" \
            else deployed
        return any(isinstance(leaf, want)
                   for leaf in jax.tree_util.tree_leaves(
                       self.params,
                       is_leaf=lambda x: isinstance(x, deployed)))

    def _jit(self, fn, **jit_kwargs):
        """jit ``fn`` with the engine's matmul + decode-attention backends
        active at trace time — both are part of the traced program, and
        each engine owns its jit cache, so traces never leak across
        backends."""
        backend, attn = self.backend, self.attn_backend

        @functools.wraps(fn)
        def run(params, *args, **kwargs):
            params = self._unpad_params(params)
            with matmul_backend(backend), paged_attn_backend(attn):
                return fn(params, *args, **kwargs)
        return jax.jit(run, **jit_kwargs)

    # ---- sharding helpers -----------------------------------------------
    def _place(self, tree, spec_fn, *args):
        """device_put every leaf per its logical-rule PartitionSpec."""
        with use_mesh(self.mesh):
            specs = spec_fn(tree, *args)
        return jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            tree, specs)

    def _place_params(self, tree):
        """Padded param placement: fit specs with padding enabled, zero-pad
        every leaf to its padded shape at the placement boundary, and
        device_put evenly — so a non-dividing vocab/kv-head dim shards on
        the model axis instead of replicating.  True shapes are remembered
        and every jitted entry point slices back (``_unpad_params``)
        before the model ever sees the tree."""
        if not self.padded_sharding:
            return self._place(tree, param_pspecs)
        with use_mesh(self.mesh):
            specs = param_pspecs(tree, pad=True)
        if self._pad_shapes is None:
            # flat list (tuples are pytrees, so not storable as leaves);
            # draft_params share every leaf shape with params
            self._pad_shapes = [tuple(x.shape)
                                for x in jax.tree_util.tree_leaves(tree)]
        return jax.tree_util.tree_map(
            lambda x, s: jax.device_put(
                pad_leaf(x, s, self.mesh), NamedSharding(self.mesh, s)),
            tree, specs)

    def _unpad_params(self, params):
        """In-graph mask side of padded placement: slice each leaf back to
        its true shape (identity when nothing was padded)."""
        if self._pad_shapes is None:
            return params
        flat, treedef = jax.tree_util.tree_flatten(params)
        return jax.tree_util.tree_unflatten(
            treedef, [unpad_leaf(x, s)
                      for x, s in zip(flat, self._pad_shapes)])

    def _shard_inputs(self, batch):
        return batch if self.mesh is None else self._place(batch,
                                                           batch_pspecs)

    def _shard_state(self, state, n_slots: int):
        # pad=False: the decode state round-trips through the donated step
        # unchanged, so it cannot carry placement padding — an uneven
        # KV-head dim serves replicated here (padded mode covers weights)
        return state if self.mesh is None else self._place(
            state, functools.partial(cache_pspecs, pad=False), n_slots)

    # ---- core ops (scheduler building blocks) ---------------------------
    def prefill(self, batch: Dict[str, jnp.ndarray], extra_slots: int = 0,
                place_state: bool = True) -> tuple:
        """Whole-prompt forward; returns (last-token logits, decode state).

        ``place_state=False`` skips the mesh placement of the returned
        state (for callers that reshape it first, e.g. the scheduler's
        lazy broadcast init)."""
        batch = self._shard_inputs(batch)
        with use_mesh(self.mesh):
            logits, state = self._prefill_j(self.params, batch,
                                            extra_slots=extra_slots)
        if place_state:
            state = self._shard_state(state, batch["tokens"].shape[0])
        return logits, state

    def prefill_at(self, batch: Dict[str, jnp.ndarray], state: Any,
                   slot) -> tuple:
        """Insert a prompt into batch row ``slot`` of a live decode state."""
        batch = self._shard_inputs(batch)
        with use_mesh(self.mesh):
            return self._prefill_at_j(self.params, batch, state, slot)

    def prefill_chunk_at(self, batch: Dict[str, jnp.ndarray], state: Any,
                         slot, start) -> tuple:
        """Insert a prompt chunk at cache position ``start`` of row
        ``slot``; returns (full (1, W, V) chunk logits, updated state)."""
        batch = self._shard_inputs(batch)
        with use_mesh(self.mesh):
            return self._prefill_chunk_j(
                self.params, batch, state,
                jnp.asarray(slot, jnp.int32), jnp.asarray(start, jnp.int32))

    def init_decode_state(self, example: Dict[str, jnp.ndarray],
                          n_slots: int, max_len: int, page_size: int = 0,
                          n_pages: Optional[int] = None) -> Any:
        """Empty (zeroed) decode state for the continuous-batching
        scheduler — paged when ``page_size > 0`` — placed per
        ``cache_pspecs`` under an active mesh."""
        state = self.api.init_decode_state(self.params, example, n_slots,
                                           max_len, page_size=page_size,
                                           n_pages=n_pages)
        return self._shard_state(state, n_slots)

    def set_tables(self, state: Any, tables) -> Any:
        """Push host-side block tables ((n_slots, nb) int32) into every
        paged KV sub-dict of ``state`` (broadcast over each stack dim).
        Allocation is host-owned (scheduler free list); storage is
        device-owned — only this tiny map crosses per change."""
        tables = np.asarray(tables, np.int32)

        def walk(cache):
            if isinstance(cache, dict):
                if "table" in cache:
                    stack = cache["table"].shape[0]
                    t = jnp.asarray(
                        np.broadcast_to(tables[None], (stack, *tables.shape)))
                    if self.mesh is not None:
                        t = jax.device_put(t, NamedSharding(
                            self.mesh, PartitionSpec()))
                    return dict(cache, table=t)
                return {k: walk(v) for k, v in cache.items()}
            return cache
        return dict(state, cache=walk(state["cache"]))

    def decode(self, tokens: jnp.ndarray, state: Any, index) -> tuple:
        """One decode step; ``index`` is a () or per-slot (B,) fill level."""
        if self.mesh is not None:
            put = self._shard_inputs({"tokens": tokens, "index": index})
            tokens, index = put["tokens"], put["index"]
        with use_mesh(self.mesh):
            return self._decode_j(self.params, tokens, state, index)

    def draft_decode(self, tokens: jnp.ndarray, state: Any, index) -> tuple:
        """One decode step with the truncated-mask draft tree.

        Identical shapes/treedef to :meth:`decode` (the draft tree shares
        every payload tensor with the deployed one), so it reuses the same
        compiled decode executable — no second trace, no second weight
        copy.  Draft K/V writes are transient: the verify pass rewrites
        every drafted position at full precision before it can be read
        below the accepted fill level."""
        if self.draft_params is None:
            raise ValueError("engine built without speculate_planes")
        if self.mesh is not None:
            put = self._shard_inputs({"tokens": tokens, "index": index})
            tokens, index = put["tokens"], put["index"]
        with use_mesh(self.mesh):
            return self._decode_j(self.draft_params, tokens, state, index)

    def verify(self, tokens: jnp.ndarray, state: Any, index) -> tuple:
        """Batched W-token verify forward with the full deployed tree.

        ``tokens`` (B, W): each slot's last accepted token followed by its
        draft proposals; returns ((B, W, V) logits, state) with all W
        positions (re)written at full precision."""
        if self._verify_j is None:
            raise ValueError("engine built without speculate_planes")
        if self.mesh is not None:
            put = self._shard_inputs({"tokens": tokens, "index": index})
            tokens, index = put["tokens"], put["index"]
        with use_mesh(self.mesh):
            return self._verify_j(self.params, tokens, state, index)

    # ---- preemption / prefix-cache state plumbing ------------------------
    def park_slot(self, state: Any, slot: int, pages) -> Dict[str, Any]:
        """Snapshot everything batch row ``slot`` holds to host memory:
        its pool pages (in ``pages``/block order) from every paged KV
        sub-dict, its row of every non-paged per-slot cache leaf
        (recurrent state), and its encoder buffer row if the family has
        one.  Pure ``np.asarray`` of the stored representation —
        quantized-at-rest payloads and scales cross as raw bytes, no
        dequantization — so :meth:`restore_slot` round-trips
        bit-identically (the PX1/PX3 contracts and the preemption leg of
        the stress suite rely on this)."""
        ids = np.asarray(pages, np.int32)
        rec: Dict[str, Any] = {"pages": {}, "rows": {}, "enc_out": None,
                               "n_pages": len(ids)}

        def walk(cache, path):
            if isinstance(cache, dict):
                if "table" in cache:
                    for name, leaf in cache["pages"].items():
                        rec["pages"][f"{path}.{name}"] = \
                            np.asarray(leaf[:, ids])
                    return
                for k, v in cache.items():
                    walk(v, f"{path}.{k}")
                return
            rec["rows"][path] = np.asarray(cache[:, slot])

        walk(state["cache"], "cache")
        if "enc_out" in state:
            rec["enc_out"] = np.asarray(state["enc_out"][slot])
        return rec

    def restore_slot(self, state: Any, slot: int, pages,
                     record: Dict[str, Any]) -> Any:
        """Write a :meth:`park_slot` snapshot back into batch row ``slot``,
        landing the parked pool pages on the freshly allocated ``pages``
        (same count, any ids — the caller rewrites its block-table row to
        match).  The inverse of parking, bit for bit."""
        if len(pages) != record["n_pages"]:
            raise ValueError(f"snapshot holds {record['n_pages']} pages, "
                             f"restore got {len(pages)} page ids")
        ids = jnp.asarray(np.asarray(pages, np.int32))

        def walk(cache, path):
            if isinstance(cache, dict):
                if "table" in cache:
                    new = {name: (leaf.at[:, ids].set(
                                      jnp.asarray(record["pages"]
                                                  [f"{path}.{name}"]))
                                  if record["n_pages"] else leaf)
                           for name, leaf in cache["pages"].items()}
                    return dict(cache, pages=new)
                return {k: walk(v, f"{path}.{k}") for k, v in cache.items()}
            return cache.at[:, slot].set(jnp.asarray(record["rows"][path]))

        out = dict(state, cache=walk(state["cache"], "cache"))
        if record.get("enc_out") is not None and "enc_out" in state:
            out["enc_out"] = state["enc_out"].at[slot].set(
                jnp.asarray(record["enc_out"]))
        return out

    def copy_pool_page(self, state: Any, src: int, dst: int) -> Any:
        """Copy pool page ``src`` onto ``dst`` in every paged KV sub-dict
        (payloads and scales alike) — the scheduler's copy-on-write
        primitive for diverging from a shared prefix page."""
        def walk(cache):
            if isinstance(cache, dict):
                if "table" in cache:
                    return dict(cache, pages={
                        name: leaf.at[:, dst].set(leaf[:, src])
                        for name, leaf in cache["pages"].items()})
                return {k: walk(v) for k, v in cache.items()}
            return cache
        return dict(state, cache=walk(state["cache"]))

    def prompt_width(self, batch: Dict[str, jnp.ndarray]) -> int:
        """Cache positions a prompt occupies (tokens + VLM vision prefix)."""
        p = batch["tokens"].shape[1]
        if self.api.cfg.family == "vlm":
            p += self.api.cfg.vision_tokens
        return p

    # ---- one-shot API (static batch) ------------------------------------
    def generate(self, batch: Dict[str, jnp.ndarray], max_new: int = 16,
                 greedy: bool = True, key=None, temperature: float = 1.0,
                 top_k: int = 0) -> jnp.ndarray:
        """batch: prompt inputs per the model family. Returns (B, max_new).

        ``greedy`` (or no ``key``) takes per-step argmax; otherwise tokens
        are drawn at ``temperature`` over the ``top_k`` best logits.
        With ``speculate_planes`` set and greedy sampling, decoding runs
        the draft/verify protocol — token-identical output, fewer
        full-precision passes."""
        if self.speculate_planes and (greedy or key is None):
            return self._generate_speculative(batch, max_new)
        logits, state = self.prefill(batch, extra_slots=_roundup64(max_new))
        prompt_len = self.prompt_width(batch)
        sp = SamplingParams(temperature=temperature, top_k=top_k)

        def pick(logits, key):
            if greedy or key is None:
                return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            return sample_token(logits, sp, key)[:, None]

        def split(key):
            return jax.random.split(key) if key is not None else (None, None)

        outs: List[jnp.ndarray] = []
        key, sub = split(key)
        tok = pick(logits, sub)       # first token sampled like the rest
        outs.append(tok[:, 0])
        index = jnp.asarray(prompt_len, jnp.int32)
        for _ in range(max_new - 1):  # max_new-1 steps, like the scheduler
            logits, state = self.decode(tok, state, index)
            key, sub = split(key)
            tok = pick(logits, sub)
            outs.append(tok[:, 0])
            index = index + 1
        return jnp.stack(outs, axis=1)

    def _generate_speculative(self, batch: Dict[str, jnp.ndarray],
                              max_new: int) -> jnp.ndarray:
        """Greedy static-batch decoding via draft/verify rounds.

        Rows accept different draft counts per round, so fill levels are
        per-row (B,) vectors; a row that reaches ``max_new`` simply stops
        taking tokens (its index freezes, later writes overwrite masked
        headroom).  The extra ``draft_gamma + 1`` headroom keeps every
        write inside the cache."""
        from .autotune.speculative import greedy_verify
        gamma = self.draft_gamma
        logits, state = self.prefill(
            batch, extra_slots=_roundup64(max_new + gamma + 1))
        prompt_len = self.prompt_width(batch)
        b = batch["tokens"].shape[0]
        outs: List[List[int]] = [[int(t)] for t in
                                 np.asarray(jnp.argmax(logits, -1))]
        counts = np.ones((b,), dtype=np.int64)
        index = np.full((b,), prompt_len, dtype=np.int64)
        tok = jnp.asarray([[o[-1]] for o in outs], jnp.int32)
        while int(counts.min()) < max_new:
            g = min(gamma, max_new - int(counts.min()) - 1)
            if g < 1:                      # last token: plain decode step
                logits, state = self.decode(
                    tok, state, jnp.asarray(index, jnp.int32))
                nxt = np.asarray(jnp.argmax(logits, -1))
                accepted = [np.asarray([t]) for t in nxt]
            else:
                cur, drafts = tok, []
                for j in range(g):
                    lg, state = self.draft_decode(
                        cur, state, jnp.asarray(index + j, jnp.int32))
                    cur = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
                    drafts.append(cur)
                vtoks = jnp.concatenate([tok] + drafts, axis=1)  # (B, g+1)
                vlogits, state = self.verify(
                    vtoks, state, jnp.asarray(index, jnp.int32))
                accepted, _ = greedy_verify(np.asarray(vtoks[:, 1:]),
                                            np.asarray(vlogits))
            for r in range(b):
                take = min(len(accepted[r]), max_new - int(counts[r]))
                outs[r].extend(int(t) for t in accepted[r][:take])
                counts[r] += take
                index[r] += take
            tok = jnp.asarray([[o[-1]] for o in outs], jnp.int32)
        return jnp.asarray([o[:max_new] for o in outs], jnp.int32)

    # ---- request-level API ----------------------------------------------
    def make_scheduler(self, requests, n_slots: int = 8,
                       max_len: Optional[int] = None,
                       page_size: Optional[int] = None,
                       n_pages: Optional[int] = None,
                       prefill_chunk: Optional[int] = None,
                       overcommit: Optional[float] = None,
                       prefix_cache: Optional[bool] = None):
        """Continuous-batching scheduler sized for ``requests``.

        ``max_len`` (total per-slot cache width) defaults to the widest
        request's prompt plus 64-rounded generation headroom — the same
        rounding ``generate`` uses, so both paths compile identical decode
        shapes.  ``page_size`` / ``n_pages`` / ``prefill_chunk`` /
        ``overcommit`` / ``prefix_cache`` default to the engine's settings
        (0 = contiguous slots / monolithic prefill; 1.0 = reservation-safe
        admission; False = no prompt-page sharing).  The scheduler is the
        stats surface too (``cache_report()``)."""
        from .scheduler import Scheduler
        if max_len is None:
            max_len = max(self.prompt_width(r.inputs) +
                          _roundup64(r.sampling.max_new_tokens)
                          for r in requests)
        return Scheduler(
            self, n_slots=n_slots, max_len=max_len,
            page_size=self.page_size if page_size is None else page_size,
            n_pages=self.n_pages if n_pages is None else n_pages,
            prefill_chunk=(self.prefill_chunk if prefill_chunk is None
                           else prefill_chunk),
            overcommit=self.overcommit if overcommit is None else overcommit,
            prefix_cache=(self.prefix_cache if prefix_cache is None
                          else prefix_cache))

    def serve(self, requests, n_slots: int = 8,
              max_len: Optional[int] = None,
              page_size: Optional[int] = None,
              n_pages: Optional[int] = None,
              prefill_chunk: Optional[int] = None,
              overcommit: Optional[float] = None,
              prefix_cache: Optional[bool] = None):
        """Run ``requests`` through a continuous-batching scheduler (see
        :meth:`make_scheduler`); results come back in submission order."""
        return self.make_scheduler(
            requests, n_slots=n_slots, max_len=max_len,
            page_size=page_size, n_pages=n_pages,
            prefill_chunk=prefill_chunk, overcommit=overcommit,
            prefix_cache=prefix_cache).run(requests)
