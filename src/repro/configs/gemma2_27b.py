"""gemma2-27b [dense] — 46L d=4608 32H (GQA kv=16) d_ff=36864 vocab=256000,
alternating local(4096)/global attention, attn softcap 50, logit softcap 30,
post-norms [arXiv:2408.00118; hf]."""
from .base import ModelConfig
from ..models.common import QuantConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_head=128,
    d_ff=36864, vocab=256000, sliding_window=4096, alt_local_global=True,
    attn_softcap=50.0, logit_softcap=30.0, use_post_norms=True,
    rope_theta=1e4, tie_embeddings=True, dtype="bfloat16",
    quant=QuantConfig(mode="fake", n_bits=8, act_bits=8, wb_rows=8, wb_cols=128),
)
