"""qwen2-vl-2b [vlm] — 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
M-RoPE, vision frontend is a STUB (input_specs supplies patch embeddings)
[arXiv:2409.12191; hf]."""
from .base import ModelConfig
from ..models.common import QuantConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_head=128,
    d_ff=8960, vocab=151936, mrope=True, qkv_bias=True, vision_tokens=256,
    rope_theta=1e6, tie_embeddings=True, dtype="bfloat16",
    quant=QuantConfig(mode="fake", n_bits=8, act_bits=8, wb_rows=8, wb_cols=128),
)
