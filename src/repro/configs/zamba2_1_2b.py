"""zamba2-1.2b [hybrid] — 38 Mamba2 layers d=2048, ssm_state=64, plus ONE
shared attention+MLP block (32H kv=32, d_ff=8192) invoked every 6 layers on
concat(hidden, embedding) [arXiv:2411.15242; hf]."""
from .base import ModelConfig
from ..models.common import QuantConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab=32000, ssm_state=64, ssm_expand=2, ssm_headdim=64,
    hybrid_attn_every=6, rope_theta=1e4, tie_embeddings=True,
    dtype="bfloat16", quant=QuantConfig(mode="fake", n_bits=8, act_bits=8, wb_rows=8, wb_cols=128),
)
