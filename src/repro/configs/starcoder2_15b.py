"""starcoder2-15b [dense] — 40L d=6144 48H (GQA kv=4) d_ff=24576
vocab=49152, GQA + RoPE, gelu MLP with qkv bias [arXiv:2402.19173; hf]."""
from .base import ModelConfig
from ..models.common import QuantConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, d_head=128,
    d_ff=24576, vocab=49152, mlp_kind="gelu", qkv_bias=True,
    rope_theta=1e5, tie_embeddings=True, dtype="bfloat16",
    quant=QuantConfig(mode="fake", n_bits=8, act_bits=8, wb_rows=8, wb_cols=128),
)
