"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from .base import LM_SHAPES, LONG_CONTEXT_OK, ModelConfig, ShapeCell, cells_for
from .granite_moe_3b_a800m import CONFIG as granite_moe_3b_a800m
from .llama4_scout_17b_a16e import CONFIG as llama4_scout_17b_a16e
from .phi3_mini_3_8b import CONFIG as phi3_mini_3_8b
from .starcoder2_15b import CONFIG as starcoder2_15b
from .deepseek_7b import CONFIG as deepseek_7b
from .gemma2_27b import CONFIG as gemma2_27b
from .zamba2_1_2b import CONFIG as zamba2_1_2b
from .rwkv6_1_6b import CONFIG as rwkv6_1_6b
from .qwen2_vl_2b import CONFIG as qwen2_vl_2b
from .seamless_m4t_large_v2 import CONFIG as seamless_m4t_large_v2

REGISTRY = {c.name: c for c in [
    granite_moe_3b_a800m, llama4_scout_17b_a16e, phi3_mini_3_8b,
    starcoder2_15b, deepseek_7b, gemma2_27b, zamba2_1_2b, rwkv6_1_6b,
    qwen2_vl_2b, seamless_m4t_large_v2,
]}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]
