"""Architecture config schema + shape cells (assigned benchmark grid)."""
from __future__ import annotations

import dataclasses
from typing import Tuple

from ..models.common import QuantConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                # 0 => d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    hybrid_attn_every: int = 0     # zamba2: shared attn block period
    # attention flavor
    mlp_kind: str = "swiglu"       # swiglu | gelu | relu
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0        # gemma2 local layers
    alt_local_global: bool = False
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    mrope: bool = False            # qwen2-vl
    use_post_norms: bool = False   # gemma2
    tie_embeddings: bool = True
    # enc-dec
    is_encdec: bool = False
    enc_layers: int = 0
    conformer_encoder: bool = False
    kv_cache_bits: int = 16        # 16 = bf16 cache; 8 = int8-quantized
    kv_cache_scale: float = 0.25   # static dequant scale for int8 caches
    ssm_chunk: int = 128           # SSD chunk length
    rwkv_chunk: int = 32           # WKV chunk length (overflow-bounded)
    # quantization (BWQ-A)
    quant: QuantConfig = QuantConfig()
    # training details
    remat: bool = True
    scan_layers: bool = True
    dtype: str = "float32"         # activation/compute dtype
    # vlm stub
    vision_tokens: int = 0         # prefix patch-embedding slots

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def with_quant(self, qc: QuantConfig) -> "ModelConfig":
        return dataclasses.replace(self, quant=qc)

    def tiny(self, **over) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        def shrink_vocab(v):
            return min(v, 512)
        base = dict(
            n_layers=min(self.n_layers, 2 if not self.hybrid_attn_every else 4),
            d_model=128, n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads
            else 4,
            d_ff=256, vocab=shrink_vocab(self.vocab), d_head=32,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=32 if self.ssm_state else 64,
            sliding_window=min(self.sliding_window, 16)
            if self.sliding_window else 0,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            vision_tokens=min(self.vision_tokens, 16)
            if self.vision_tokens else 0,
            remat=False,
        )
        base.update(over)
        return dataclasses.replace(self, **base)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


LM_SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)

# archs allowed to run long_500k (sub-quadratic state path); the rest skip
# it per the assignment (see DESIGN.md §5).
LONG_CONTEXT_OK = ("rwkv6-1.6b", "zamba2-1.2b")


def cells_for(cfg: ModelConfig):
    for cell in LM_SHAPES:
        if cell.name == "long_500k" and cfg.name not in LONG_CONTEXT_OK:
            continue
        yield cell
