"""seamless-m4t-large-v2 [audio] — enc-dec 24L+24L d=1024 16H (kv=16)
d_ff=8192 vocab=256206; conformer-style speech encoder with STUB frontend
(input_specs supplies frame embeddings) [arXiv:2308.11596; hf]."""
from .base import ModelConfig
from ..models.common import QuantConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=8192, vocab=256206, is_encdec=True, enc_layers=24,
    conformer_encoder=True, mlp_kind="gelu", tie_embeddings=True,
    dtype="bfloat16", quant=QuantConfig(mode="fake", n_bits=8, act_bits=8, wb_rows=8, wb_cols=128),
)
