"""granite-moe-3b-a800m [moe] — 32L d=1536 24H (GQA kv=8) d_ff=512/expert,
vocab=49155, 40 experts top-8 [hf:ibm-granite/granite-3.0 family; hf]."""
from .base import ModelConfig
from ..models.common import QuantConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_head=64,
    d_ff=512, vocab=49155, n_experts=40, top_k=8,
    rope_theta=1e4, tie_embeddings=True, dtype="bfloat16",
    quant=QuantConfig(mode="fake", n_bits=8, act_bits=8, wb_rows=8, wb_cols=128),
)
