"""rwkv6-1.6b 'Finch' [ssm] — 24L d=2048 attn-free, data-dependent decay,
channel-mix d_ff=7168, vocab=65536 [arXiv:2404.05892; unverified]."""
from .base import ModelConfig
from ..models.common import QuantConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=7168, vocab=65536, tie_embeddings=True, dtype="bfloat16",
    quant=QuantConfig(mode="fake", n_bits=8, act_bits=8, wb_rows=8, wb_cols=128),
)
