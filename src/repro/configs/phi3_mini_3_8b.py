"""phi3-mini-3.8b [dense] — 32L d=3072 32H (kv=32) d_ff=8192 vocab=32064,
RoPE + SwiGLU [arXiv:2404.14219; unverified]."""
from .base import ModelConfig
from ..models.common import QuantConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_head=96,
    d_ff=8192, vocab=32064, rope_theta=1e4, tie_embeddings=True,
    dtype="bfloat16", quant=QuantConfig(mode="fake", n_bits=8, act_bits=8, wb_rows=8, wb_cols=128),
)
