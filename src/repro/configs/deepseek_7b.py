"""deepseek-7b [dense] — 30L d=4096 32H (kv=32) d_ff=11008 vocab=102400,
llama-arch [arXiv:2401.02954; hf]."""
from .base import ModelConfig
from ..models.common import QuantConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, d_head=128,
    d_ff=11008, vocab=102400, rope_theta=1e4, tie_embeddings=True,
    dtype="bfloat16", quant=QuantConfig(mode="fake", n_bits=8, act_bits=8, wb_rows=8, wb_cols=128),
)
