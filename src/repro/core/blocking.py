"""Weight-block (WB) partitioning.

The paper partitions every weight matrix into 2-D Weight Blocks whose shape
equals the hardware Operation Unit (OU): ``wb_rows`` wordlines (input dim)
by ``wb_cols`` bitlines (output dim).  Fully-connected weights ``(K, N)``
(K = fan-in, N = fan-out) are partitioned directly; convolutional weights
``(C_out, C_in, kh, kw)`` are first flattened to ``(C_in*kh*kw, C_out)``
following the CSP reshaping (paper §III-A, Fig. 2).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class BlockingSpec:
    """Shape bookkeeping for partitioning a (K, N) matrix into WBs.

    Paper-faithful OU is 9x8 (9 WLs x 8 BLs).  TPU-aligned variants (e.g.
    8x128) are supported as the OU-size scalability axis of the paper §VI-D.
    """

    wb_rows: int = 9   # wordlines  = input-dim rows per block (0 = whole dim)
    wb_cols: int = 8   # bitlines   = output-dim cols per block (0 = whole dim)

    def resolve(self, k: int, n: int) -> "BlockingSpec":
        """Concrete spec for a (k, n) matrix; 0-dims become the full extent
        (whole-layer blocks = the BSQ layer-wise baseline)."""
        if self.wb_rows and self.wb_cols:
            return self
        return BlockingSpec(self.wb_rows or k, self.wb_cols or n)

    def grid(self, k: int, n: int) -> Tuple[int, int]:
        """Number of blocks (GR, GC) covering a (k, n) matrix (ceil)."""
        r = self.resolve(k, n)
        return (-(-k // r.wb_rows), -(-n // r.wb_cols))

    def padded(self, k: int, n: int) -> Tuple[int, int]:
        gr, gc = self.grid(k, n)
        return gr * self.wb_rows, gc * self.wb_cols


def conv_to_2d(w: jnp.ndarray) -> jnp.ndarray:
    """CSP reshape: (C_out, C_in, kh, kw) -> (C_in*kh*kw, C_out)."""
    c_out = w.shape[0]
    return jnp.transpose(w.reshape(c_out, -1))


def conv_from_2d(w2d: jnp.ndarray, conv_shape: Tuple[int, ...]) -> jnp.ndarray:
    """Inverse of :func:`conv_to_2d`."""
    return jnp.transpose(w2d).reshape(conv_shape)


def pad_to_blocks(w: jnp.ndarray, spec: BlockingSpec) -> jnp.ndarray:
    """Zero-pad the trailing two dims of ``w`` to block multiples."""
    k, n = w.shape[-2], w.shape[-1]
    kp, np_ = spec.padded(k, n)
    if (kp, np_) == (k, n):
        return w
    pad = [(0, 0)] * (w.ndim - 2) + [(0, kp - k), (0, np_ - n)]
    return jnp.pad(w, pad)


def block_view(w: jnp.ndarray, spec: BlockingSpec) -> jnp.ndarray:
    """(..., Kp, Np) -> (..., GR, GC, wb_rows, wb_cols).

    ``w`` must already be padded to block multiples.
    """
    *lead, kp, np_ = w.shape
    gr, gc = kp // spec.wb_rows, np_ // spec.wb_cols
    w = w.reshape(*lead, gr, spec.wb_rows, gc, spec.wb_cols)
    # (..., GR, wb_rows, GC, wb_cols) -> (..., GR, GC, wb_rows, wb_cols)
    return jnp.moveaxis(w, -3, -2)


def unblock_view(wb: jnp.ndarray, spec: BlockingSpec) -> jnp.ndarray:
    """Inverse of :func:`block_view`: (..., GR, GC, r, c) -> (..., Kp, Np)."""
    *lead, gr, gc, r, c = wb.shape
    wb = jnp.moveaxis(wb, -2, -3)  # (..., GR, r, GC, c)
    return wb.reshape(*lead, gr * r, gc * c)


def expand_block_map(per_block: jnp.ndarray, spec: BlockingSpec) -> jnp.ndarray:
    """Broadcast a per-block map (..., GR, GC) to elements (..., Kp, Np)."""
    x = jnp.repeat(per_block, spec.wb_rows, axis=-2)
    return jnp.repeat(x, spec.wb_cols, axis=-1)


def block_count(shape_kn: Tuple[int, int], spec: BlockingSpec) -> int:
    gr, gc = spec.grid(*shape_kn)
    return int(np.prod((gr, gc)))


def block_elem_counts(shape_kn: Tuple[int, int],
                      spec: BlockingSpec) -> jnp.ndarray:
    """(GR, GC) count of *real* (unpadded) weight elements in each block.

    Edge blocks are partial when K/N are not block multiples; bit-count and
    compression-ratio accounting must not bill the padding."""
    k, n = shape_kn
    gr, gc = spec.grid(k, n)
    rows = jnp.clip(k - jnp.arange(gr) * spec.wb_rows, 0, spec.wb_rows)
    cols = jnp.clip(n - jnp.arange(gc) * spec.wb_cols, 0, spec.wb_cols)
    return rows[:, None] * cols[None, :]
