"""Block-wise precision adjustment (paper Fig. 3b).

For every WB, scan its bit planes from the MSB downwards; while a plane is
all-zero inside the block, clear its mask bit; stop at the first non-zero
plane.  The resulting mask is always a *prefix* mask: ones for bits
``[0, bitwidth)``, zeros above.  Precision is monotonically non-increasing
because the new mask is intersected with the old one.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .bitrep import QuantizedTensor
from .blocking import block_view


def plane_block_any(planes: jnp.ndarray, spec) -> jnp.ndarray:
    """(n, ..., Kp, Np) -> (n, ..., GR, GC): does bit b have any non-zero in WB g?"""
    def per_plane(p):
        bw = block_view(p, spec)                     # (..., GR, GC, r, c)
        return jnp.any(bw != 0, axis=(-1, -2))
    return jax.vmap(per_plane)(planes)


def prefix_mask_from_nonzero(nz: jnp.ndarray, dtype) -> jnp.ndarray:
    """Build the paper's MSB-down prefix mask from per-(bit, block) nonzeros.

    bitwidth(g) = 1 + max{b : nz[b, g]}  (0 if all planes zero); then
    mask[b, g] = b < bitwidth(g).
    """
    n = nz.shape[0]
    bit_idx = jnp.arange(n).reshape((n,) + (1,) * (nz.ndim - 1))
    highest = jnp.max(jnp.where(nz, bit_idx + 1, 0), axis=0)   # (..., GR, GC)
    return (bit_idx < highest[None]).astype(dtype)


def adjust_precision(qt: QuantizedTensor) -> QuantizedTensor:
    """Apply block-wise precision adjustment; returns a new QuantizedTensor."""
    nz = plane_block_any(qt.planes * 1.0, qt.spec)
    # Only planes that are currently live can keep the block alive.
    nz = jnp.logical_and(nz, qt.mask > 0)
    new_mask = prefix_mask_from_nonzero(nz, qt.mask.dtype)
    new_mask = jnp.minimum(new_mask, qt.mask)      # monotone: never re-grow
    return dataclasses.replace(qt, mask=new_mask)
