"""Bit-level weight representation (paper Eq. 1).

``W = sign(W) * s / (2^n - 1) * sum_b W_s^(b) * 2^b * m^(g,b)``

The bit tensor ``planes`` is trained as continuous non-negative floats
(BSQ-style relaxation); re-quantization (``repro.core.quantize``) snaps it
back to exact binary at scheduled intervals.  The per-(block, bit) mask ``m``
is binary and non-trainable; precision adjustment only ever clears bits.

A :class:`QuantizedTensor` is a pytree, so it can live inside model params,
be differentiated (grads flow to ``planes`` and ``scale``) and be sharded by
pjit like any other leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .blocking import (BlockingSpec, block_view, expand_block_map,
                       pad_to_blocks)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QuantizedTensor:
    """Bit-level representation of one weight matrix (or a stacked (L, K, N))."""

    planes: jnp.ndarray        # (n_bits, ..., Kp, Np) non-negative float
    sign: jnp.ndarray          # (..., Kp, Np) in {-1, +1}
    scale: jnp.ndarray         # per-layer () / (L,) or per-block (..., GR, GC)
    mask: jnp.ndarray          # (n_bits, ..., GR, GC) in {0., 1.}
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    spec: BlockingSpec = dataclasses.field(metadata=dict(static=True))

    @property
    def n_bits(self) -> int:
        return self.planes.shape[0]

    def astype_planes(self, dtype) -> "QuantizedTensor":
        return dataclasses.replace(self, planes=self.planes.astype(dtype))


def _levels(n_bits: int) -> float:
    return float(2 ** n_bits - 1)


def from_float(w: jnp.ndarray, n_bits: int = 8,
               spec: Optional[BlockingSpec] = None,
               per_block_scale: bool = False) -> QuantizedTensor:
    """Decompose a float matrix (..., K, N) into its bit-level representation."""
    spec = (spec or BlockingSpec()).resolve(w.shape[-2], w.shape[-1])
    shape = tuple(w.shape)
    wp = pad_to_blocks(w, spec)
    sign = jnp.where(wp < 0, -1.0, 1.0).astype(wp.dtype)
    absw = jnp.abs(wp)
    if per_block_scale:
        bw = block_view(absw, spec)                      # (..., GR, GC, r, c)
        scale = jnp.max(bw, axis=(-1, -2))               # (..., GR, GC)
        scale = jnp.maximum(scale, 1e-8)
        s_full = expand_block_map(scale, spec)
    else:
        reduce_axes = tuple(range(absw.ndim - 2, absw.ndim))
        scale = jnp.maximum(jnp.max(absw, axis=reduce_axes), 1e-8)  # () or (L,)
        s_full = scale[..., None, None] if scale.ndim else scale
    q = jnp.round(absw / s_full * _levels(n_bits))
    q = jnp.clip(q, 0, _levels(n_bits))
    planes = extract_planes(q, n_bits)                   # (n, ..., Kp, Np)
    gr, gc = spec.grid(shape[-2], shape[-1])
    lead = shape[:-2]
    mask = jnp.ones((n_bits, *lead, gr, gc), dtype=wp.dtype)
    return QuantizedTensor(planes=planes, sign=sign, scale=scale, mask=mask,
                           shape=shape, spec=spec)


def extract_planes(q_int: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Integer tensor (values in [0, 2^n-1]) -> binary planes (n, ...)."""
    q = q_int.astype(jnp.int32)
    planes = [((q >> b) & 1).astype(q_int.dtype) for b in range(n_bits)]
    return jnp.stack(planes, axis=0)


def compose_int(qt: QuantizedTensor) -> jnp.ndarray:
    """sum_b planes[b] * 2^b * m[b]  (continuous during training)."""
    n = qt.n_bits
    weights = (2.0 ** jnp.arange(n, dtype=qt.planes.dtype))
    m_full = jax.vmap(lambda m: expand_block_map(m, qt.spec))(qt.mask)
    contrib = qt.planes * m_full                          # (n, ..., Kp, Np)
    return jnp.tensordot(weights, contrib, axes=(0, 0))   # (..., Kp, Np)


def compose(qt: QuantizedTensor, dtype=None) -> jnp.ndarray:
    """Materialize the float weight matrix (..., K, N) per paper Eq. 1."""
    q = compose_int(qt)
    if qt.scale.ndim >= 1 and qt.scale.shape[-2:] == qt.mask.shape[-2:]:
        s_full = expand_block_map(qt.scale, qt.spec)
    elif qt.scale.ndim:
        s_full = qt.scale[..., None, None]
    else:
        s_full = qt.scale
    w = qt.sign * q * (s_full / _levels(qt.n_bits))
    k, n_ = qt.shape[-2], qt.shape[-1]
    w = w[..., :k, :n_]
    return w.astype(dtype) if dtype is not None else w


def live_bits(qt: QuantizedTensor) -> jnp.ndarray:
    """Total live (unmasked) bit count, counting wb elements under each mask."""
    per_block = float(qt.spec.wb_rows * qt.spec.wb_cols)
    return jnp.sum(qt.mask) * per_block


def bitwidths(qt: QuantizedTensor) -> jnp.ndarray:
    """Per-block effective bit-width (n_bits axis reduced): (..., GR, GC)."""
    return jnp.sum(qt.mask, axis=0)


def param_count(qt: QuantizedTensor) -> int:
    k, n_ = qt.shape[-2], qt.shape[-1]
    lead = 1
    for d in qt.shape[:-2]:
        lead *= d
    return lead * k * n_
