"""Pytree utilities for models whose params contain QuantizedTensor leaves."""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from .bitrep import QuantizedTensor, bitwidths, param_count
from .group_lasso import layer_bit_count

_is_qt = lambda x: isinstance(x, QuantizedTensor)


def quantized_leaves(params: Any) -> Dict[str, QuantizedTensor]:
    """All QuantizedTensor leaves keyed by their pytree path string."""
    out: Dict[str, QuantizedTensor] = {}
    flat = jax.tree_util.tree_flatten_with_path(params, is_leaf=_is_qt)[0]
    for path, leaf in flat:
        if _is_qt(leaf):
            out[jax.tree_util.keystr(path)] = leaf
    return out


def map_quantized(fn: Callable[[QuantizedTensor], QuantizedTensor],
                  params: Any) -> Any:
    """Apply ``fn`` to every QuantizedTensor leaf, pass through the rest."""
    return jax.tree_util.tree_map(
        lambda x: fn(x) if _is_qt(x) else x, params, is_leaf=_is_qt)


def quant_summary(params: Any) -> Dict[str, float]:
    """Aggregate compression statistics across all quantized layers."""
    qts = quantized_leaves(params)
    if not qts:
        return dict(layers=0, avg_bitwidth=0.0, compression_x=1.0,
                    total_params=0)
    total_params = sum(param_count(q) for q in qts.values())
    total_bits = sum(float(layer_bit_count(q)) for q in qts.values())
    avg_bw = total_bits / max(total_params, 1)
    return dict(layers=len(qts),
                avg_bitwidth=avg_bw,
                compression_x=32.0 * total_params / max(total_bits, 1.0),
                total_params=total_params)


def per_layer_bitwidth_maps(params: Any) -> Dict[str, jnp.ndarray]:
    """Per-layer (GR, GC) bit-width heatmaps (paper Fig. 7)."""
    return {k: bitwidths(q) for k, q in quantized_leaves(params).items()}
