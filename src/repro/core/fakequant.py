"""Memory-scalable BWQ-A mode for billion-parameter training.

The paper trains weights in bit-level representation (8 float planes per
weight = 8x weight memory) — fine for CIFAR CNNs, prohibitive for 27B-70B
LMs.  ``FakeQuantTensor`` keeps one float master weight plus the per-WB
bit-width LUT and applies the *identical inference-time semantics* through
a straight-through fake-quantization: round to the layer scale grid and
saturate each WB at its ``2^bw - 1`` magnitude ceiling.  For exact-binary
states this composes bit-for-bit the same weight as the bit-plane mode
(property-tested in tests/test_fakequant.py).

Differences vs. the paper-faithful mode (documented, DESIGN.md §6):
* the group-Lasso surrogate is a per-WB L2 on the scaled weights (the
  bit-plane Lasso needs the planes, which are not materialized here);
* re-quantization snaps the master weight onto the quantization grid.
Precision adjustment (MSB-down) is exact in both modes and monotone.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .bitrep import _levels
from .blocking import BlockingSpec, block_view, expand_block_map, pad_to_blocks
from .quantize import ste_round


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FakeQuantTensor:
    w: jnp.ndarray          # (..., K, N) float master weight
    scale: jnp.ndarray      # per-layer (lead dims) scale
    bitwidth: jnp.ndarray   # (..., GR, GC) float live-bit LUT
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    spec: BlockingSpec = dataclasses.field(metadata=dict(static=True))
    n_bits: int = dataclasses.field(default=8, metadata=dict(static=True))


def fq_from_float(w: jnp.ndarray, n_bits: int = 8,
                  spec: BlockingSpec | None = None) -> FakeQuantTensor:
    spec = (spec or BlockingSpec()).resolve(w.shape[-2], w.shape[-1])
    shape = tuple(w.shape)
    reduce_axes = (w.ndim - 2, w.ndim - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=reduce_axes), 1e-8)
    gr, gc = spec.grid(shape[-2], shape[-1])
    bitwidth = jnp.full((*shape[:-2], gr, gc), float(n_bits), dtype=w.dtype)
    return FakeQuantTensor(w=w, scale=scale, bitwidth=bitwidth, shape=shape,
                           spec=spec, n_bits=n_bits)


def _scale_full(fq: FakeQuantTensor, padded_shape) -> jnp.ndarray:
    s = fq.scale
    return s[..., None, None] if s.ndim else s


def fq_compose(fq: FakeQuantTensor, dtype=None) -> jnp.ndarray:
    """STE fake-quantized weight with per-WB saturation (Eq. 1 semantics)."""
    wp = pad_to_blocks(fq.w, fq.spec)
    s = _scale_full(fq, wp.shape)
    levels = _levels(fq.n_bits)
    q = ste_round(jnp.abs(wp) / s * levels)
    cap = expand_block_map(2.0 ** fq.bitwidth - 1.0, fq.spec)
    q = jnp.clip(q, 0.0, cap)
    w = jnp.where(wp < 0, -1.0, 1.0) * q * (s / levels)
    k, n = fq.shape[-2], fq.shape[-1]
    w = w[..., :k, :n]
    return w.astype(dtype) if dtype is not None else w


def fq_maintenance(fq: FakeQuantTensor) -> FakeQuantTensor:
    """Re-quantize + block-wise precision adjustment (monotone).

    Snaps ``w`` to the grid, recomputes each WB's minimal bit-width
    (position of the highest set bit over the block) and intersects it
    with the previous LUT so precision never grows back.
    """
    wp = pad_to_blocks(fq.w, fq.spec)
    s = _scale_full(fq, wp.shape)
    levels = _levels(fq.n_bits)
    cap = expand_block_map(2.0 ** fq.bitwidth - 1.0, fq.spec)
    q = jnp.clip(jnp.round(jnp.abs(wp) / s * levels), 0.0, cap)
    # highest set bit per WB -> required precision
    blk_max = jnp.max(block_view(q, fq.spec), axis=(-1, -2))
    need = jnp.ceil(jnp.log2(blk_max + 1.0))
    new_bw = jnp.minimum(fq.bitwidth, need)
    w_snapped = jnp.where(wp < 0, -1.0, 1.0) * q * (s / levels)
    k, n = fq.shape[-2], fq.shape[-1]
    w_snapped = w_snapped[..., :k, :n]
    return dataclasses.replace(fq, w=w_snapped.astype(fq.w.dtype),
                               bitwidth=new_bw)


def fq_group_lasso(fq: FakeQuantTensor) -> jnp.ndarray:
    """Per-WB L2 surrogate of the bit-level group Lasso (scale-normalized)."""
    wp = pad_to_blocks(fq.w, fq.spec)
    s = _scale_full(fq, wp.shape)
    bw = block_view(wp / s, fq.spec)
    sq = jnp.sum(bw * bw, axis=(-1, -2))
    alive = (fq.bitwidth > 0).astype(wp.dtype)
    return jnp.sum(jnp.sqrt(sq + 1e-12) * alive)


def fq_live_bits(fq: FakeQuantTensor) -> jnp.ndarray:
    from .blocking import block_elem_counts
    elems = block_elem_counts((fq.shape[-2], fq.shape[-1]), fq.spec)
    return jnp.sum(fq.bitwidth * elems.astype(fq.bitwidth.dtype))
