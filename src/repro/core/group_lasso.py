"""WB-level group Lasso regularizer (paper Eqs. 2-3).

``B_GL(W^r) = sum_g sum_b || W_s^(g,b) * m^(g,b) ||_2``

The total objective weights each layer's regularizer by
``#Param(W^r) * #Bit(W^r) / #Param(total)`` so that layers holding more bits
are penalized harder (Eq. 3).
"""
from __future__ import annotations

from typing import Dict, Iterable

import jax
import jax.numpy as jnp

from .bitrep import QuantizedTensor, param_count
from .blocking import block_view


def wb_group_lasso(qt: QuantizedTensor) -> jnp.ndarray:
    """sum over (block, bit) groups of the L2 norm of the masked plane."""
    def per_plane(p, m):
        bw = block_view(p, qt.spec)                         # (..., GR, GC, r, c)
        sq = jnp.sum(bw * bw, axis=(-1, -2))                # (..., GR, GC)
        return jnp.sum(jnp.sqrt(sq + 1e-12) * m)
    vals = jax.vmap(per_plane)(qt.planes, qt.mask)          # (n_bits,)
    return jnp.sum(vals)


def layer_bit_count(qt: QuantizedTensor) -> jnp.ndarray:
    """Current total live bits in the layer (edge-block padding excluded)."""
    from .blocking import block_elem_counts
    elems = block_elem_counts((qt.shape[-2], qt.shape[-1]), qt.spec)
    elems = elems.astype(qt.mask.dtype)          # (GR, GC), broadcasts over
    return jnp.sum(qt.mask * elems)              # (n, ..., GR, GC)


def regularization_loss(qts: Dict[str, QuantizedTensor],
                        alpha: float) -> jnp.ndarray:
    """Paper Eq. 3 second term over all quantized layers.

    The per-layer coefficient uses the *current* (stop-gradient) live bit
    count so the schedule tracks compression as it happens.
    """
    if not qts or alpha == 0.0:
        return jnp.asarray(0.0)
    total_params = float(sum(param_count(q) for q in qts.values()))
    loss = 0.0
    for q in qts.values():
        coeff = jax.lax.stop_gradient(layer_bit_count(q)) / total_params
        loss = loss + coeff * wb_group_lasso(q)
    return alpha * loss


def model_compression_ratio(qts: Iterable[QuantizedTensor],
                            float_bits: int = 32) -> float:
    """Compression ratio vs a float baseline (paper Table II 'Comp.')."""
    qts = list(qts)
    total_params = sum(param_count(q) for q in qts)
    total_bits = sum(float(jax.device_get(layer_bit_count(q))) for q in qts)
    if total_bits == 0:
        return float("inf")
    return float_bits * total_params / total_bits
