"""PACT activation clipping + uniform activation quantization (paper Eq. 4).

``y = PACT(x) = 0.5 (|x| - |x - beta| + beta)``  clips to [0, beta] with a
trainable clip level beta (gradient flows to beta on the saturated side),
followed by uniform quantization to ``act_bits`` with a straight-through
estimator.
"""
from __future__ import annotations

import jax.numpy as jnp

from .quantize import ste_round


def pact(x: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    """Parameterized clipping (Eq. 4); differentiable in x and beta."""
    return 0.5 * (jnp.abs(x) - jnp.abs(x - beta) + beta)


def pact_quant(x: jnp.ndarray, beta: jnp.ndarray, act_bits: int) -> jnp.ndarray:
    """PACT clip then quantize to ``act_bits`` levels (STE gradients)."""
    y = pact(x, beta)
    if act_bits >= 32:
        return y
    levels = float(2 ** act_bits - 1)
    b = jnp.maximum(beta, 1e-6)
    q = ste_round(y / b * levels)
    return q * (b / levels)


def pact_sym(x: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    """Symmetric PACT (TPU/transformer adaptation): clip to [-beta, beta].

    The paper's PACT (Eq. 4) targets post-ReLU CNN activations; transformer
    activations are signed, so the clip is mirrored (DESIGN.md §2).
    """
    return 0.5 * (jnp.abs(x + beta) - jnp.abs(x - beta))


def pact_sym_quant(x: jnp.ndarray, beta: jnp.ndarray,
                   act_bits: int) -> jnp.ndarray:
    y = pact_sym(x, beta)
    if act_bits >= 32:
        return y
    levels = float(2 ** (act_bits - 1) - 1)
    b = jnp.maximum(beta, 1e-6)
    q = ste_round(y / b * levels)
    return (q * (b / levels)).astype(x.dtype)


def quantize_signed(x: jnp.ndarray, bits: int,
                    scale: jnp.ndarray | None = None) -> jnp.ndarray:
    """Symmetric signed uniform quantization with STE (used for KV cache)."""
    if bits >= 32:
        return x
    levels = float(2 ** (bits - 1) - 1)
    s = jnp.max(jnp.abs(x)) if scale is None else scale
    s = jnp.maximum(s, 1e-6)
    q = ste_round(jnp.clip(x / s, -1.0, 1.0) * levels)
    return q * (s / levels)
