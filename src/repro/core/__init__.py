"""BWQ-A: block-wise mixed-precision quantization (the paper's algorithm)."""
from .blocking import (BlockingSpec, block_view, conv_from_2d, conv_to_2d,
                       expand_block_map, pad_to_blocks, unblock_view)
from .bitrep import (QuantizedTensor, bitwidths, compose, compose_int,
                     extract_planes, from_float, live_bits, param_count)
from .quantize import PackedWeight, pack, requantize, ste_round, unpack_to_float
from .precision import adjust_precision, prefix_mask_from_nonzero
from .group_lasso import (layer_bit_count, model_compression_ratio,
                          regularization_loss, wb_group_lasso)
from .pact import (pact, pact_quant, pact_sym, pact_sym_quant,
                   quantize_signed)
from .fakequant import (FakeQuantTensor, fq_compose, fq_from_float,
                        fq_group_lasso, fq_live_bits, fq_maintenance)
from .policy import BWQSchedule
from .state import (map_quantized, per_layer_bitwidth_maps, quant_summary,
                    quantized_leaves)
