"""Re-quantization (paper Fig. 3a) and deployment packing.

During QAT the bit planes drift away from exact binary; at scheduled epochs
we *re-quantize*: compose the (masked) integer value of each weight, round
and clip it to the representable range, and re-extract exact binary planes.
Pruned planes (mask == 0) contribute nothing and stay zero afterwards, so
model sparsity is non-decreasing (paper §III-A).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .bitrep import QuantizedTensor, compose_int, extract_planes, _levels
from .blocking import block_view, expand_block_map


def pack_int4(q, axis: int = -1) -> jnp.ndarray:
    """Pack signed integer values (|q| < 8) as two's-complement nibble
    pairs along ``axis`` (whose length must be even): even positions land
    in the low nibble, odd in the high.  Shared by the deployment weight
    packer (serve/deploy.py, K axis) and the int4 KV cache
    (models/attention.py, head axis) so the wire format has one owner."""
    u = jnp.asarray(q).astype(jnp.int32) & 0xF
    um = jnp.moveaxis(u, axis, -1)
    lo, hi = um[..., 0::2], um[..., 1::2]
    return jnp.moveaxis((lo | (hi << 4)).astype(jnp.uint8), -1, axis)


def unpack_int4(u, axis: int = -1) -> jnp.ndarray:
    """Inverse of :func:`pack_int4`: uint8 nibble pairs -> int32 values in
    [-8, 7], interleaved back along ``axis`` (length doubles)."""
    um = jnp.moveaxis(u, axis, -1)
    lo = (um & 0xF).astype(jnp.int32)
    hi = ((um >> 4) & 0xF).astype(jnp.int32)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    st = jnp.stack([lo, hi], axis=-1).reshape(*um.shape[:-1], -1)
    return jnp.moveaxis(st, -1, axis)


def requantize(qt: QuantizedTensor, rescale: bool = False) -> QuantizedTensor:
    """Snap the continuous bit planes back to exact binary values."""
    q = compose_int(qt)                                   # (..., Kp, Np)
    q = jnp.clip(jnp.round(q), 0.0, _levels(qt.n_bits))
    planes = extract_planes(q, qt.n_bits).astype(qt.planes.dtype)
    new = dataclasses.replace(qt, planes=planes)
    if rescale:
        # Optional (beyond-paper): refit per-block scale to the surviving range.
        bw = block_view(q, qt.spec)
        blk_max = jnp.max(bw, axis=(-1, -2))
        if qt.scale.shape[-2:] == qt.mask.shape[-2:] and qt.scale.ndim >= 2:
            denom = jnp.maximum(blk_max, 1.0)
            new = dataclasses.replace(
                new, scale=qt.scale * denom / _levels(qt.n_bits))
    return new


class PackedWeight(NamedTuple):
    """Deployment layout: integer magnitudes + per-block metadata.

    ``values`` holds sign*magnitude as int8 (covers n_bits <= 7 exactly; for
    8-bit blocks magnitudes occupy [0, 255] so we keep int16 in that case).
    ``bitwidth`` is the memory-controller LUT of the paper (per-WB bit count).
    """

    values: jnp.ndarray     # (..., Kp, Np) int8/int16 signed magnitudes
    scale: jnp.ndarray      # per-layer or per-block scale
    bitwidth: jnp.ndarray   # (..., GR, GC) int32
    shape: tuple
    n_bits: int


def pack(qt: QuantizedTensor) -> PackedWeight:
    """QAT representation -> deployment representation (after requantize)."""
    q = jnp.clip(jnp.round(compose_int(qt)), 0.0, _levels(qt.n_bits))
    signed = (qt.sign * q)
    dt = jnp.int16 if qt.n_bits >= 8 else jnp.int8
    values = signed.astype(dt)
    bw = jnp.sum(qt.mask, axis=0).astype(jnp.int32)
    return PackedWeight(values=values, scale=qt.scale, bitwidth=bw,
                        shape=qt.shape, n_bits=qt.n_bits)


def unpack_to_float(pw: PackedWeight, spec, dtype=jnp.float32) -> jnp.ndarray:
    """Dequantize a PackedWeight back to (..., K, N) float (reference path)."""
    vals = pw.values.astype(dtype)
    if pw.scale.ndim >= 2 and pw.scale.shape[-2:] == pw.bitwidth.shape[-2:]:
        s_full = expand_block_map(pw.scale.astype(dtype), spec)
    elif pw.scale.ndim:
        s_full = pw.scale.astype(dtype)[..., None, None]
    else:
        s_full = pw.scale.astype(dtype)
    w = vals * (s_full / _levels(pw.n_bits))
    k, n_ = pw.shape[-2], pw.shape[-1]
    return w[..., :k, :n_]


def ste_round(x: jnp.ndarray) -> jnp.ndarray:
    """Straight-through rounding (identity gradient)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)
