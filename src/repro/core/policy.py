"""QAT schedule for BWQ-A (paper Algorithm 1).

The paper's outer loops (grow alpha until >1% accuracy loss; then lower the
activation precision until >1% loss) are driven by ``repro.train.loop``;
this module holds the schedule state and the step-level decisions
(when to re-quantize + precision-adjust).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class BWQSchedule:
    init_weight_bits: int = 8
    init_act_bits: int = 8
    alpha: float = 0.0              # current regularization strength
    delta_alpha: float = 5e-4       # Alg. 1 outer-loop increment
    requant_interval: int = 200     # steps between re-quantization events
    acc_drop_budget: float = 0.01   # 1% (paper)
    per_block_scale: bool = False   # paper-faithful: per-layer scale
    wb_rows: int = 9
    wb_cols: int = 8

    def is_requant_step(self, step: int) -> bool:
        return step > 0 and self.requant_interval > 0 and \
            step % self.requant_interval == 0

    def bump_alpha(self) -> "BWQSchedule":
        return dataclasses.replace(self, alpha=self.alpha + self.delta_alpha)

    def lower_act_bits(self) -> "BWQSchedule":
        return dataclasses.replace(self, init_act_bits=self.init_act_bits - 1)
