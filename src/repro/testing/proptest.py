"""Deterministic fallback property-test driver (a `hypothesis` micro-shim).

``hypothesis`` is an *optional* dependency; historically the property
suites were ``importorskip``-gated, so environments without it silently
lost all randomized coverage.  This module implements the tiny subset of
the hypothesis API those suites use — ``@given`` / ``@settings`` and the
``integers`` / ``floats`` / ``sampled_from`` / ``booleans`` /
``composite`` strategies — driven by a ``random.Random`` seeded from the
test's name, so without the real library the same tests still run a
bounded, *deterministic* set of drawn cases (no shrinking, no example
database; a failure reports the falsifying draw so it can be pinned as a
regression case).

Usage (test modules):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from repro.testing import proptest as _pt
        given, settings, st = _pt.given, _pt.settings, _pt
"""
from __future__ import annotations

import functools
import random
from typing import Any, Callable, Sequence

DEFAULT_MAX_EXAMPLES = 20


class Strategy:
    """A value generator: ``example(rng)`` draws one value."""

    def __init__(self, fn: Callable[[random.Random], Any]):
        self._fn = fn

    def example(self, rng: random.Random) -> Any:
        return self._fn(rng)

    def map(self, f: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng: f(self._fn(rng)))


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> Strategy:
    return Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements: Sequence) -> Strategy:
    elements = list(elements)
    return Strategy(lambda rng: elements[rng.randrange(len(elements))])


def booleans() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5)


class _Draw:
    def __init__(self, rng: random.Random):
        self.rng = rng

    def __call__(self, strategy: Strategy) -> Any:
        return strategy.example(self.rng)


def composite(f: Callable) -> Callable[..., Strategy]:
    """``@composite``-decorated builders take ``draw`` as first argument."""
    @functools.wraps(f)
    def builder(*args, **kwargs) -> Strategy:
        return Strategy(lambda rng: f(_Draw(rng), *args, **kwargs))
    return builder


def settings(**kwargs) -> Callable:
    """Records ``max_examples`` (other hypothesis knobs are ignored);
    composes with :func:`given` in either decorator order."""
    def deco(fn):
        fn._prop_settings = dict(kwargs)
        return fn
    return deco


def given(*strategies: Strategy, **kw_strategies: Strategy) -> Callable:
    """Run the test once per drawn example (seeded by the test name)."""
    def deco(fn):
        # metadata only — NOT functools.wraps: exposing the wrapped
        # signature (__wrapped__) would make pytest treat the drawn
        # parameters as fixtures
        def run(*args, **kwargs):
            cfg = getattr(run, "_prop_settings",
                          getattr(fn, "_prop_settings", {}))
            n = cfg.get("max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"proptest:{fn.__module__}.{fn.__name__}")
            for i in range(n):
                vals = [s.example(rng) for s in strategies]
                kvals = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *vals, **kvals, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (draw {i + 1}/{n}): "
                        f"args={vals} kwargs={kvals}") from e
        run.__name__ = fn.__name__
        run.__qualname__ = fn.__qualname__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        run.__dict__.update(fn.__dict__)   # carries pytest marks/settings
        return run
    return deco
