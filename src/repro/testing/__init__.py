"""Test-support utilities (fallback property-test driver)."""
from . import proptest  # noqa: F401
