"""BWQ-H: OU-based ReRAM accelerator model + baselines (paper §IV-§VI)."""
from .spec import HardwareSpec, PAPER_SPEC
from .mapping import MappingCost, layer_mapping_cost, wb_mapping_cost
from .controller import ControllerTrace, controller_cycles, lut_bits, run_controller
from .simulator import (LayerReport, LayerWorkload, Scheme, SimReport,
                        bsq_scheme, bwq_scheme, isaac_scheme,
                        simulate, simulate_layer, sme_scheme,
                        speedup_and_energy_saving, sre_scheme)
from .workloads import (conv_workload, fc_workload, workload_from_qt,
                        workloads_from_params)
