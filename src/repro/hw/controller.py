"""Memory-controller cycle model (paper Algorithm 2 + Fig. 6b).

The controller walks the WB grid of one crossbar: for every WB with
non-zero precision it activates one OU per live bit plane (one cycle each,
accumulating ADC outputs into the psum with a shift-left), emits an S&A
*skip* signal between WBs so psums of different WBs never mix, and raises
the IR *fetch* signal when a row of WBs completes so the next activation
slice is loaded.

``trace`` reproduces the event sequence of Fig. 6(b) and is what the unit
tests check; ``cycles`` is the count the simulator consumes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass
class ControllerTrace:
    events: List[Tuple[int, int, int, int]]  # (cycle, vblock, hblock, bit)
    sna_skips: int
    ir_fetches: int

    @property
    def cycles(self) -> int:
        return len(self.events)


def run_controller(bitwidths: np.ndarray) -> ControllerTrace:
    """Execute Algorithm 2 over a (Vblocks, Hblocks) bit-width table.

    Rows of the table are input (wordline) blocks, columns are output
    (bitline) blocks; one event per OU activation.
    """
    bw = np.asarray(bitwidths, dtype=np.int64)
    vblocks, hblocks = bw.shape
    events, skips, fetches = [], 0, 0
    cycle = 0
    for i in range(vblocks):                 # activation slice (IR section)
        for j in range(hblocks):
            p = int(bw[i, j])
            if p == 0:
                continue                     # spare OU group: skipped entirely
            for b in range(p):
                events.append((cycle, i, j, b))
                cycle += 1
            skips += 1                       # psum boundary after each WB
        fetches += 1                         # next IR slice after the WB row
    return ControllerTrace(events=events, sna_skips=skips, ir_fetches=fetches)


def controller_cycles(bitwidths: np.ndarray, act_bits: int = 1) -> int:
    """OU-activation cycles for one full pass, with bit-serial inputs.

    With 1-bit DACs each OU activation is repeated ``act_bits`` times
    (one input bit per pass), so total cycles = act_bits * sum(bitwidths).
    """
    return int(act_bits) * int(np.sum(np.asarray(bitwidths, dtype=np.int64)))


def lut_bits(bitwidths: np.ndarray, max_bits: int = 8) -> int:
    """Size of the controller's per-WB bit-width LUT in bits."""
    entry = int(np.ceil(np.log2(max_bits + 1)))
    return int(np.prod(np.asarray(bitwidths).shape)) * entry
