"""End-to-end BWQ-H performance/energy simulator (MNSIM-style analytical).

Workloads are per-layer VMM descriptions; schemes (BWQ-H and the paper's
baselines ISAAC / SRE / SME / BSQ) decide how many OU activations a layer
needs and what peripheral overheads apply.  Reported quantities:

* latency  — OU/ADC-limited compute time plus the buffer/accumulation time
  of the "unoptimized components" (this term produces the paper's VGG19
  speedup-saturation effect, §VI-B);
* energy   — per-component breakdown (array, DAC, ADC, buffer, S&A, ctrl);
* index    — scheme-specific indexing/metadata storage (paper Fig. 11).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from .mapping import layer_mapping_cost
from .spec import HardwareSpec, PAPER_SPEC


@dataclasses.dataclass
class LayerWorkload:
    """One VMM layer: y[positions, n] = x[positions, k] @ W[k, n]."""
    name: str
    k: int                       # fan-in (C_in*kh*kw  or  d_in)
    n: int                       # fan-out
    positions: int               # VMM invocations (H_out*W_out, tokens, ...)
    bitwidths: Optional[np.ndarray] = None   # (GR, GC) per-WB bits (BWQ)
    act_bits: int = 8
    weight_zero_frac: float = 0.0  # fraction of zero weight values (for SRE/SME)

    def grid(self, ou_rows: int, ou_cols: int):
        return (math.ceil(self.k / ou_rows), math.ceil(self.n / ou_cols))


@dataclasses.dataclass
class LayerReport:
    name: str
    cycles: float
    latency_s: float
    energy_j: Dict[str, float]
    index_bits: float

    @property
    def total_energy(self) -> float:
        return sum(self.energy_j.values())


@dataclasses.dataclass
class SimReport:
    layers: List[LayerReport]

    @property
    def latency_s(self) -> float:
        return sum(l.latency_s for l in self.layers)

    @property
    def energy_j(self) -> float:
        return sum(l.total_energy for l in self.layers)

    def energy_breakdown(self) -> Dict[str, float]:
        keys = self.layers[0].energy_j.keys() if self.layers else []
        return {k: sum(l.energy_j[k] for l in self.layers) for k in keys}

    @property
    def index_bits(self) -> float:
        return sum(l.index_bits for l in self.layers)


# ---------------------------------------------------------------------------
# scheme definitions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scheme:
    """How a given accelerator executes a layer under the OU regime."""
    name: str
    weight_bits: Optional[int] = None   # None => use the learned per-WB table
    act_bits: Optional[int] = None      # None => use the workload's act bits
    mapping: str = "precision_aware"
    # fraction of OU activations skipped via sparsity indexing (SRE/SME)
    ou_skip_frac: float = 0.0
    # indexing metadata bits per *kept* OU row / per WB / per layer
    index_bits_per_ou_row: float = 0.0
    index_bits_per_wb: float = 0.0
    index_bits_per_xbar_row: float = 0.0
    uses_controller: bool = False


def bwq_scheme() -> Scheme:
    # 4-bit LUT entry per WB (bit-widths 0..8)
    return Scheme("BWQ-H", mapping="precision_aware",
                  index_bits_per_wb=4, uses_controller=True)


def bsq_scheme(layer_bits: int = 4) -> Scheme:
    # layer-uniform precision; negligible indexing (one entry per layer)
    return Scheme("BSQ", weight_bits=layer_bits, mapping="same_ou")


def isaac_scheme() -> Scheme:
    # 16-bit weights/acts, 1-bit cells (paper's modification), no compression
    return Scheme("ISAAC", weight_bits=16, act_bits=16, mapping="same_ou")


def sre_scheme(effective_compression: float = 3.3) -> Scheme:
    """SRE @ 9x8 OUs: ~3.3x compression from OU-row sparsity (paper §VI-B),
    paid for with per-OU-row indexing (7-bit row index + presence bit)."""
    return Scheme("SRE", weight_bits=16, act_bits=16, mapping="same_ou",
                  ou_skip_frac=1.0 - 1.0 / effective_compression,
                  index_bits_per_ou_row=16)   # 9b row idx + 7b offset ptr


def sme_scheme(effective_compression: float = 16.0 / 4.0) -> Scheme:
    """SME: <=3 consecutive non-zero bits after PTQ (~4 effective bits incl.
    offset metadata); crossbar-row squeeze-out; tiny per-row indexing."""
    return Scheme("SME", weight_bits=4, act_bits=16, mapping="conventional",
                  index_bits_per_xbar_row=1)   # squeeze-out flag per row


# ---------------------------------------------------------------------------
# simulation
# ---------------------------------------------------------------------------

def simulate_layer(wl: LayerWorkload, scheme: Scheme,
                   spec: HardwareSpec = PAPER_SPEC) -> LayerReport:
    gr, gc = wl.grid(spec.ou_rows, spec.ou_cols)
    act_bits = scheme.act_bits if scheme.act_bits is not None else wl.act_bits

    if scheme.weight_bits is None:
        if wl.bitwidths is None:
            raise ValueError(f"{scheme.name} needs a per-WB bit-width table")
        bw_table = np.asarray(wl.bitwidths, dtype=np.int64)
    else:
        bw_table = np.full((gr, gc), scheme.weight_bits, dtype=np.int64)

    mc = layer_mapping_cost(bw_table, spec.ou_cols, scheme.mapping)
    ou_acts = mc.ou_activations * (1.0 - scheme.ou_skip_frac)

    # ---- compute / ADC path ------------------------------------------
    adc_cycles = spec.adc_cycles_at(spec.adc_bits)
    ou_total = wl.positions * act_bits * ou_acts
    cycles = ou_total * adc_cycles
    t_compute = cycles / (spec.n_xbars * spec.freq_hz)

    # ---- unoptimized components (buffer + accumulation) ---------------
    in_bits = wl.positions * wl.k * act_bits
    out_bits = wl.positions * wl.n * 24            # psum accumulator width
    t_buffer = (in_bits + out_bits) / (
        spec.buffer_bits * spec.n_xbars * spec.freq_hz)
    latency = t_compute + t_buffer

    # ---- energy --------------------------------------------------------
    convs = ou_total * spec.ou_cols
    e = dict(
        adc=convs * spec.e_adc_conv_at(spec.adc_bits),
        dac=ou_total * spec.ou_rows * spec.e_dac_bit,
        array=ou_total * spec.e_array_ou,
        sna=(convs + wl.positions * act_bits * mc.extra_sna_ops)
            * spec.e_sna_op,
        buffer=(in_bits + out_bits) * spec.e_buffer_bit,
        ctrl=(cycles * spec.e_ctrl_cycle) if scheme.uses_controller else 0.0,
    )

    # ---- indexing metadata ----------------------------------------------
    kept_ou_rows = gr * spec.ou_rows * (1.0 - scheme.ou_skip_frac) \
        * math.ceil(wl.n * (scheme.weight_bits or 8) / spec.ou_cols)
    index_bits = (
        scheme.index_bits_per_wb * gr * gc
        + scheme.index_bits_per_ou_row * kept_ou_rows
        + scheme.index_bits_per_xbar_row
        * (wl.k * math.ceil(wl.n * (scheme.weight_bits or 8)
                            / spec.xbar_cols)))
    return LayerReport(wl.name, cycles, latency, e, index_bits)


def simulate(workloads: List[LayerWorkload], scheme: Scheme,
             spec: HardwareSpec = PAPER_SPEC) -> SimReport:
    return SimReport([simulate_layer(w, scheme, spec) for w in workloads])


def speedup_and_energy_saving(workloads: List[LayerWorkload],
                              scheme: Scheme, baseline: Scheme,
                              spec: HardwareSpec = PAPER_SPEC):
    a = simulate(workloads, scheme, spec)
    b = simulate(workloads, baseline, spec)
    return b.latency_s / a.latency_s, b.energy_j / a.energy_j
