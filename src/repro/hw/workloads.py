"""Build hardware workloads from quantized JAX models / layer shapes."""
from __future__ import annotations

import math
from typing import Any, List

import numpy as np

from ..core.bitrep import QuantizedTensor, bitwidths
from ..core.state import quantized_leaves
from .simulator import LayerWorkload
from .spec import HardwareSpec, PAPER_SPEC


def workload_from_qt(name: str, qt: QuantizedTensor, positions: int,
                     act_bits: int) -> LayerWorkload:
    """LayerWorkload from a trained QuantizedTensor (uses its learned LUT)."""
    bw = np.asarray(bitwidths(qt))
    if bw.ndim > 2:                       # stacked (L, GR, GC): treat layers
        bw = bw.reshape(-1, bw.shape[-1])
    k, n = qt.shape[-2], qt.shape[-1]
    lead = int(np.prod(qt.shape[:-2])) if qt.shape[:-2] else 1
    planes = np.asarray(qt.planes)
    zero_frac = float(np.mean(np.all(planes == 0, axis=0)))
    return LayerWorkload(name=name, k=k * 1, n=n * lead,
                         positions=positions, bitwidths=bw,
                         act_bits=act_bits, weight_zero_frac=zero_frac)


def workloads_from_params(params: Any, positions: int = 1,
                          act_bits: int = 8) -> List[LayerWorkload]:
    return [workload_from_qt(name, qt, positions, act_bits)
            for name, qt in quantized_leaves(params).items()]


# -- shape-only workloads (no trained state): used for config-level studies --

def conv_workload(name: str, c_in: int, c_out: int, ksize: int,
                  h_out: int, w_out: int, act_bits: int = 8,
                  weight_bits: int = 8,
                  spec: HardwareSpec = PAPER_SPEC) -> LayerWorkload:
    k = c_in * ksize * ksize
    gr, gc = math.ceil(k / spec.ou_rows), math.ceil(c_out / spec.ou_cols)
    bw = np.full((gr, gc), weight_bits, dtype=np.int64)
    return LayerWorkload(name, k, c_out, h_out * w_out, bw, act_bits)


def fc_workload(name: str, d_in: int, d_out: int, positions: int = 1,
                act_bits: int = 8, weight_bits: int = 8,
                spec: HardwareSpec = PAPER_SPEC) -> LayerWorkload:
    gr = math.ceil(d_in / spec.ou_rows)
    gc = math.ceil(d_out / spec.ou_cols)
    bw = np.full((gr, gc), weight_bits, dtype=np.int64)
    return LayerWorkload(name, d_in, d_out, positions, bw, act_bits)
