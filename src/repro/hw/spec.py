"""BWQ-H hardware specification (paper Table I) and derived device models.

All constants are chip-level at 1.2 GHz; per-operation energies are derived
so that full-utilization power matches Table I.  The ADC model scales
energy exponentially and latency linearly with resolution (SAR ADC), which
is the scaling the paper's §VI-D OU sweep relies on.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    # memristor array
    xbar_rows: int = 128
    xbar_cols: int = 128
    bits_per_cell: int = 1
    ou_rows: int = 9           # concurrently-on wordlines
    ou_cols: int = 8           # concurrently-on bitlines (= ADCs per xbar)
    # peripherals
    dac_bits: int = 1
    adc_bits: int = 4          # ceil(log2(ou_rows + 1)) for 1-bit cells
    freq_hz: float = 1.2e9
    # chip-level composition
    n_tiles: int = 16
    banks_per_tile: int = 8
    # Table I power (W), chip total 25.25 W
    p_array: float = 0.89
    p_dac: float = 0.36
    p_adc: float = 23.22
    p_buffer: float = 0.59
    p_ctrl: float = 0.0928
    p_digital: float = 0.0926
    # buffer
    buffer_bits: int = 64      # bus width per bank

    # ---- derived -----------------------------------------------------
    @property
    def n_xbars(self) -> int:
        return self.n_tiles * self.banks_per_tile

    @property
    def cycle_s(self) -> float:
        return 1.0 / self.freq_hz

    def adc_bits_for(self, ou_rows: int) -> int:
        """ADC resolution needed to resolve an OU partial sum losslessly."""
        return max(1, math.ceil(math.log2(ou_rows * (2 ** self.bits_per_cell - 1) + 1)))

    # per-op energies (J), normalized so Table-I power holds at 100% duty
    # in the PAPER's reference geometry (9x8 OU, 4-bit ADC).  The reference
    # is fixed so OU-size sweeps (with_ou) scale per-op costs physically
    # instead of silently re-normalizing the calibration.
    _REF_OU_ROWS = 9
    _REF_OU_COLS = 8
    _REF_ADC_BITS = 4

    @property
    def e_adc_conv(self) -> float:
        convs_per_s = self.freq_hz * self.n_xbars * self._REF_OU_COLS
        return self.p_adc / convs_per_s

    def e_adc_conv_at(self, adc_bits: int) -> float:
        """ADC energy/conversion ~ 2^b * b: exponential comparator/cap-DAC
        energy times the b-cycle SAR conversion (paper: "ADC energy scales
        up significantly with its precision", Fig. 13)."""
        return self.e_adc_conv * (2.0 ** (adc_bits - self._REF_ADC_BITS)) \
            * (adc_bits / self._REF_ADC_BITS)

    def adc_cycles_at(self, adc_bits: int) -> float:
        """SAR conversion latency grows linearly with resolution."""
        return max(1.0, adc_bits / self._REF_ADC_BITS)

    @property
    def e_dac_bit(self) -> float:
        bits_per_s = self.freq_hz * self.n_xbars * self._REF_OU_ROWS
        return self.p_dac / bits_per_s

    @property
    def e_array_ou(self) -> float:
        ou_per_s = self.freq_hz * self.n_xbars
        return self.p_array / ou_per_s

    @property
    def e_buffer_bit(self) -> float:
        bits_per_s = self.freq_hz * self.n_xbars * self.buffer_bits
        return self.p_buffer / bits_per_s

    @property
    def e_ctrl_cycle(self) -> float:
        return self.p_ctrl / (self.freq_hz * self.n_xbars)

    @property
    def e_sna_op(self) -> float:
        return self.p_digital / (self.freq_hz * self.n_xbars)

    def with_ou(self, ou_rows: int, ou_cols: int) -> "HardwareSpec":
        """Clone with a different OU geometry (paper Fig. 13 sweep)."""
        return dataclasses.replace(
            self, ou_rows=ou_rows, ou_cols=ou_cols,
            adc_bits=self.adc_bits_for(ou_rows))


PAPER_SPEC = HardwareSpec()
