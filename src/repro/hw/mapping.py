"""Weight-mapping schemes for mixed-precision WBs onto OUs (paper Fig. 5).

Three schemes for placing the bits of a WB's weight vectors on crossbar
columns:

* ``conventional``  — bits of one weight in consecutive columns; weights may
  straddle OU boundaries, requiring cross-OU shift-and-add indexing logic
  (extra S&A control ops) — Fig. 5(a).
* ``same_ou``       — a weight's bits never straddle an OU; spare columns are
  wasted when ``ou_cols % bits != 0`` — Fig. 5(b).
* ``precision_aware`` — bit-plane slicing: OU *k* of a WB holds bit *k* of
  all ``ou_cols`` weights; 100 % utilization, no cross-OU indexing —
  Fig. 5(c), the paper's contribution.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class MappingCost:
    ou_activations: float    # OU turn-ons to read the whole WB once
    utilization: float       # fraction of activated cells holding live bits
    extra_sna_ops: float     # cross-OU accumulation ops beyond the baseline


def wb_mapping_cost(bits: int, ou_cols: int, scheme: str) -> MappingCost:
    """Cost of reading one WB (``ou_cols`` weights wide) at ``bits`` precision."""
    if bits <= 0:
        return MappingCost(0.0, 1.0, 0.0)
    total_cols = ou_cols * bits                     # live cells per OU row
    if scheme == "precision_aware":
        ous = bits                                  # one OU per bit plane
        return MappingCost(ous, 1.0, 0.0)
    if scheme == "same_ou":
        wpo = max(1, ou_cols // bits)               # weights fitting in one OU
        ous = math.ceil(ou_cols / wpo)
        used = total_cols
        return MappingCost(ous, used / (ous * ou_cols), 0.0)
    if scheme == "conventional":
        ous = math.ceil(total_cols / ou_cols)
        # every weight vector that straddles an OU boundary needs an extra
        # cross-OU shift-add with indexing control
        straddles = sum(1 for w in range(ou_cols)
                        if (w * bits) // ou_cols != (w * bits + bits - 1) // ou_cols)
        return MappingCost(ous, total_cols / (ous * ou_cols), float(straddles))
    raise ValueError(f"unknown mapping scheme: {scheme}")


def layer_mapping_cost(bitwidths: np.ndarray, ou_cols: int,
                       scheme: str) -> MappingCost:
    """Aggregate mapping cost over a (GR, GC) bit-width table."""
    bw = np.asarray(bitwidths).reshape(-1)
    ous = util_num = util_den = sna = 0.0
    # bitwidth values are small integers; group to avoid per-block python loop
    vals, counts = np.unique(bw, return_counts=True)
    for v, c in zip(vals, counts):
        mc = wb_mapping_cost(int(v), ou_cols, scheme)
        ous += c * mc.ou_activations
        util_num += c * mc.ou_activations * mc.utilization
        util_den += c * mc.ou_activations
        sna += c * mc.extra_sna_ops
    util = util_num / util_den if util_den else 1.0
    return MappingCost(ous, util, sna)
