"""Training loop: BWQ-A schedule (paper Alg. 1) + fault tolerance.

Responsibilities:
* drive train steps over the deterministic data pipeline;
* run re-quantization + precision adjustment every ``requant_interval``;
* grow the regularization strength alpha by delta_alpha per round while
  quality stays inside the budget (Alg. 1 outer loop, step-based here);
* checkpoint every N steps (atomic, async) and restore-on-start — a crash
  or preemption resumes exactly (data pipeline is index-addressable);
* optional fault injection hook for the restart tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional


from ..ckpt.checkpoint import CheckpointManager
from ..dist.sharding import get_mesh
from ..optim.optimizers import Optimizer
from .state import TrainState
from .step import build_maintenance_step, build_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 1000
    ckpt_every: int = 200
    ckpt_dir: Optional[str] = None
    log_every: int = 50
    requant_interval: int = 200
    alpha_round_steps: int = 0      # bump alpha every N steps (0 = fixed)
    delta_alpha: float = 0.0
    quality_budget: float = 0.01    # allowed degradation vs baseline quality
    keep_ckpts: int = 3


class Trainer:
    def __init__(self, loss_fn: Callable, optimizer: Optimizer,
                 lr_schedule: Callable, params: Any,
                 tcfg: TrainerConfig,
                 eval_fn: Optional[Callable[[Any], float]] = None,
                 alpha: float = 0.0):
        self.tcfg = tcfg
        self.train_step = build_train_step(loss_fn, optimizer, lr_schedule)
        self.maintenance = build_maintenance_step()
        self.state = TrainState.create(params, optimizer, alpha)
        self.eval_fn = eval_fn
        self.baseline_quality: Optional[float] = None
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep_ckpts) \
            if tcfg.ckpt_dir else None
        self.history: list = []

    # -- fault tolerance -------------------------------------------------
    def try_restore(self, template_state: Optional[TrainState] = None) -> int:
        """Resume from the latest checkpoint, resharding onto whatever
        mesh is live *now* — the restore mesh need not match the saving
        one (elastic restart)."""
        if self.ckpt is None:
            return 0
        template = template_state or self.state
        meta, restored = self.ckpt.restore_latest(template, mesh=get_mesh())
        if restored is None:
            return 0
        self.state = restored
        return int(meta[0])

    def _save(self, step: int):
        if self.ckpt is not None:
            # the active mesh shards the save: one file per host, chunked
            # by each leaf's fitted spec (single-shard with no mesh)
            self.ckpt.save(step, self.state, dict(step=step),
                           mesh=get_mesh())

    # -- main loop ---------------------------------------------------------
    def run(self, data: Iterator, steps: Optional[int] = None,
            fault_at: Optional[int] = None) -> Dict[str, Any]:
        tcfg = self.tcfg
        steps = steps or tcfg.total_steps
        start = int(self.state.step)
        t0 = time.time()
        last_metrics: Dict[str, Any] = {}
        for _ in range(start, steps):
            step_idx, batch = next(data)
            if fault_at is not None and step_idx == fault_at:
                raise RuntimeError(f"injected fault at step {step_idx}")
            self.state, metrics = self.train_step(self.state, batch)
            step = int(self.state.step)
            if tcfg.requant_interval and step % tcfg.requant_interval == 0:
                self.state = self.maintenance(self.state)
            if tcfg.alpha_round_steps and tcfg.delta_alpha and \
                    step % tcfg.alpha_round_steps == 0:
                self._alpha_round()
            if step % tcfg.log_every == 0 or step == steps:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["wall_s"] = time.time() - t0
                self.history.append(m)
                last_metrics = m
            if tcfg.ckpt_every and step % tcfg.ckpt_every == 0:
                self._save(step)
        self._save(int(self.state.step))
        if self.ckpt:
            self.ckpt.wait()
        return last_metrics

    def _alpha_round(self):
        """Alg. 1 outer loop: raise alpha while quality stays in budget."""
        if self.eval_fn is None:
            self.state = dataclasses.replace(
                self.state,
                alpha=self.state.alpha + self.tcfg.delta_alpha)
            return
        q = self.eval_fn(self.state.params)
        if self.baseline_quality is None:
            self.baseline_quality = q
        if q >= self.baseline_quality - self.tcfg.quality_budget:
            self.state = dataclasses.replace(
                self.state,
                alpha=self.state.alpha + self.tcfg.delta_alpha)


def run_with_restarts(make_trainer: Callable[[], Trainer],
                      make_data: Callable[[int], Iterator],
                      total_steps: int, fault_at: Optional[int] = None,
                      max_restarts: int = 3) -> Trainer:
    """Crash-resilient driver: rebuild trainer + restore + resume on failure.

    Demonstrates the production restart path end-to-end (used in tests)."""
    attempts = 0
    while True:
        trainer = make_trainer()
        resumed = trainer.try_restore()
        data = make_data(resumed)
        try:
            trainer.run(data, steps=total_steps,
                        fault_at=fault_at if attempts == 0 else None)
            return trainer
        except RuntimeError:
            attempts += 1
            if attempts > max_restarts:
                raise
