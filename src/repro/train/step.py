"""Jitted train / maintenance steps with BWQ-A hooks.

* ``quant_reg_loss`` — paper Eq. 3 regularizer across every quantized leaf
  (bit-plane mode: exact WB group Lasso; fake mode: the per-WB L2 surrogate).
* ``freeze_mask`` — gradients of quantization metadata (mask/sign/bitwidth/
  scale) are zeroed; only bit planes / master weights (and normal params)
  train.
* ``build_maintenance_step`` — re-quantization + block-wise precision
  adjustment, run every ``requant_interval`` steps by the loop (paper Alg 1
  lines 11-14).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..core.bitrep import QuantizedTensor, param_count
from ..core.fakequant import (FakeQuantTensor, fq_group_lasso, fq_live_bits,
                              fq_maintenance)
from ..core.group_lasso import layer_bit_count, wb_group_lasso
from ..core.precision import adjust_precision
from ..core.quantize import requantize
from ..optim.optimizers import Optimizer, global_norm
from .state import TrainState

_QTYPES = (QuantizedTensor, FakeQuantTensor)
_is_q = lambda x: isinstance(x, _QTYPES)


def _quant_nodes(params) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(params, is_leaf=_is_q)[0]
    return {jax.tree_util.keystr(p): x for p, x in flat if _is_q(x)}


def quant_reg_loss(params, alpha) -> jnp.ndarray:
    """alpha * sum_r coeff_r * B_GL(W^r)   (Eq. 3)."""
    nodes = _quant_nodes(params)
    if not nodes:
        return jnp.asarray(0.0, jnp.float32)
    total_params = float(sum(param_count(q) if isinstance(q, QuantizedTensor)
                             else int(jnp.size(q.w)) for q in nodes.values()))
    loss = jnp.asarray(0.0, jnp.float32)
    for q in nodes.values():
        if isinstance(q, QuantizedTensor):
            bits = layer_bit_count(q)
            gl = wb_group_lasso(q)
        else:
            bits = fq_live_bits(q)
            gl = fq_group_lasso(q)
        coeff = jax.lax.stop_gradient(bits) / total_params
        loss = loss + coeff.astype(jnp.float32) * gl.astype(jnp.float32)
    return alpha * loss


def quant_stats(params) -> Dict[str, jnp.ndarray]:
    nodes = _quant_nodes(params)
    if not nodes:
        return dict(avg_bitwidth=jnp.asarray(0.0),
                    compression_x=jnp.asarray(1.0))
    tot_p, tot_b = 0.0, jnp.asarray(0.0, jnp.float32)
    for q in nodes.values():
        if isinstance(q, QuantizedTensor):
            tot_p += param_count(q)
            tot_b = tot_b + layer_bit_count(q)
        else:
            tot_p += int(jnp.size(q.w))
            tot_b = tot_b + fq_live_bits(q)
    return dict(avg_bitwidth=tot_b / tot_p,
                compression_x=32.0 * tot_p / jnp.maximum(tot_b, 1.0))


_FROZEN_FIELDS = (".mask", ".sign", ".bitwidth", ".scale")


def freeze_mask(grads):
    """Zero gradients of quantization metadata leaves (by path suffix)."""
    def one(path, g):
        k = jax.tree_util.keystr(path)
        if any(k.endswith(f) for f in _FROZEN_FIELDS):
            return jnp.zeros_like(g)
        return g
    return jax.tree_util.tree_map_with_path(one, grads)


def microbatched_value_and_grad(loss_fn: Callable, num_mb: int):
    """Gradient accumulation over ``num_mb`` microbatches via lax.scan.

    Bounds activation memory to one microbatch (the standard large-batch
    trick at pod scale); grads are averaged, aux metrics come from the
    last microbatch.
    """
    if num_mb <= 1:
        return jax.value_and_grad(loss_fn, has_aux=True)

    def fn(params, batch):
        mb = jax.tree_util.tree_map(
            lambda x: x.reshape(num_mb, x.shape[0] // num_mb, *x.shape[1:]),
            batch)

        def body(carry, b):
            g_acc, l_acc = carry
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, b)
            g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
            return (g_acc, l_acc + loss), metrics

        g0 = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, x.dtype), params)
        (g, loss_sum), metrics = jax.lax.scan(body, (g0, 0.0), mb)
        g = jax.tree_util.tree_map(lambda x: x / num_mb, g)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return (loss_sum / num_mb, metrics), g

    return fn


def build_train_step(loss_fn: Callable, optimizer: Optimizer,
                     lr_schedule: Callable, donate: bool = True):
    """loss_fn(params, batch) -> (loss, metrics dict)."""

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        def total_loss(params):
            loss, metrics = loss_fn(params, batch)
            reg = quant_reg_loss(params, state.alpha)
            return loss + reg, (metrics, reg)

        (loss, (metrics, reg)), grads = jax.value_and_grad(
            total_loss, has_aux=True)(state.params)
        grads = freeze_mask(grads)
        lr = lr_schedule(state.step)
        new_params, new_opt = optimizer.update(grads, state.opt_state,
                                               state.params, lr)
        new_state = TrainState(step=state.step + 1, params=new_params,
                               opt_state=new_opt, alpha=state.alpha)
        metrics = dict(metrics, loss=loss, reg=reg, lr=lr,
                       grad_norm=global_norm(grads), **quant_stats(new_params))
        return new_state, metrics

    return jax.jit(step, donate_argnums=(0,) if donate else ())


def _maintain_leaf(q):
    if isinstance(q, QuantizedTensor):
        return adjust_precision(requantize(q))
    if isinstance(q, FakeQuantTensor):
        return fq_maintenance(q)
    return q


def build_maintenance_step():
    """Re-quantize + precision-adjust every quantized leaf (Alg 1 l.11-14)."""
    def maintain(state: TrainState) -> TrainState:
        new_params = jax.tree_util.tree_map(_maintain_leaf, state.params,
                                            is_leaf=_is_q)
        return TrainState(step=state.step, params=new_params,
                          opt_state=state.opt_state, alpha=state.alpha)
    return jax.jit(maintain, donate_argnums=(0,))
