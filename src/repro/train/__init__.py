from .state import TrainState
from .step import (build_maintenance_step, build_train_step, freeze_mask,
                   quant_reg_loss)
from .loop import Trainer, TrainerConfig
