"""Train state pytree."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    step: jnp.ndarray          # () int32
    params: Any
    opt_state: Any
    alpha: jnp.ndarray         # () f32 — BWQ regularization strength

    @classmethod
    def create(cls, params, optimizer, alpha: float = 0.0) -> "TrainState":
        return cls(step=jnp.zeros((), jnp.int32), params=params,
                   opt_state=optimizer.init(params),
                   alpha=jnp.asarray(alpha, jnp.float32))
