"""Pallas TPU kernel: bit-plane-sliced mixed-precision matmul (BWQ core).

The digital analogue of BWQ-H's precision-aware OU mapping (paper Fig. 5c):
weights live in HBM as 1-bit planes (packed 8 rows/byte) plus a packed sign
plane and the per-WB (bit, block) mask LUT.  Each grid step streams the
packed tiles HBM->VMEM ((n_bits+1)/8 bytes per weight instead of 2-4),
decodes them in-register, composes the masked magnitude, and issues ONE MXU
matmul per (m, n, k) tile.  Masked planes contribute zero exactly as the
memory controller skips their OUs.

Tiling: wb_rows | block_k and wb_cols | block_n so mask expansion is a
sublane/lane-aligned broadcast (TPU-native WB geometry 8x128; the paper's
9x8 geometry stays on the pure-jnp path — DESIGN.md §2).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pallas_utils import fit_block, pad_dim, resolve_interpret, round_up


def _kernel(x_ref, planes_ref, sign_ref, mask_ref, scale_ref, o_ref, *,
            n_bits: int, wbr: int, wbc: int, block_k: int, per_block: bool):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)              # (bm, bk)
    bn = o_ref.shape[1]

    def unpack(packed):                             # (bk//8, bn) -> (bk, bn)
        parts = [((packed >> r) & 1) for r in range(8)]
        st = jnp.stack(parts, axis=1)               # (bk//8, 8, bn)
        return st.reshape(block_k, bn)

    # compose magnitude = sum_b 2^b * plane_b * mask_b   (masked planes skip)
    mag = jnp.zeros((block_k, bn), jnp.float32)
    for b in range(n_bits):
        plane = unpack(planes_ref[b]).astype(jnp.float32)
        m = mask_ref[b].astype(jnp.float32)         # (bk//wbr, bn//wbc)
        m = jnp.repeat(jnp.repeat(m, wbr, axis=0), wbc, axis=1)
        mag = mag + (2.0 ** b) * plane * m

    sign = 1.0 - 2.0 * unpack(sign_ref[...]).astype(jnp.float32)
    if per_block:
        # per-WB effective scale (serving layout): /(2^n - 1) and each
        # block's power-of-two rescale factor are pre-folded into the LUT
        s = jnp.repeat(jnp.repeat(scale_ref[...], wbr, axis=0), wbc, axis=1)
        w = sign * mag * s
    else:
        w = sign * mag * (scale_ref[0] / (2.0 ** n_bits - 1.0))
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_bits", "wbr", "wbc",
                                             "block_m", "block_n", "block_k",
                                             "interpret"))
def bitplane_matmul(x, planes_packed, sign_packed, mask, scale, *,
                    n_bits: int = 8, wbr: int = 8, wbc: int = 128,
                    block_m: int = 128, block_n: int = 256,
                    block_k: int = 512, interpret: bool | None = None):
    """y[M,N] = x[M,K] @ compose(planes, sign, mask, scale).

    planes_packed: (n_bits, K//8, N) uint8; sign_packed: (K//8, N) uint8;
    mask: (n_bits, K//wbr, N//wbc); scale: (1,) f32 per-layer, divided by
    ``2^n - 1`` in-kernel, OR (K//wbr, N//wbc) f32 per-WB *effective*
    scale (the serving layout: /(2^n - 1) and per-block rescale factors
    pre-folded — this is what carries BWQ's mixed per-block precision to
    the MXU).  M/K/N that do not divide the tile sizes are zero-padded up
    to tile multiples and the output trimmed back; ``planes_packed`` may
    carry extra zero byte-pad rows beyond K//wbr WB rows (odd block-padded
    K under e.g. the 9x8 paper geometry packs up to the byte boundary).
    ``interpret=None`` auto-selects interpret mode off-TPU.
    """
    interpret = resolve_interpret(interpret)
    m, k = x.shape
    n = planes_packed.shape[-1]
    per_block = scale.ndim == 2
    unit_k = math.lcm(8, wbr)          # bit-packing rows AND WB rows align
    kp = round_up(k, unit_k)
    kp = max(kp, round_up(planes_packed.shape[1] * 8, unit_k))
    np_ = round_up(n, wbc)
    mp = round_up(m, 8)
    x = pad_dim(pad_dim(x, 1, kp), 0, mp)
    planes_packed = pad_dim(pad_dim(planes_packed, 1, kp // 8), 2, np_)
    sign_packed = pad_dim(pad_dim(sign_packed, 0, kp // 8), 1, np_)
    mask = pad_dim(pad_dim(mask, 1, kp // wbr), 2, np_ // wbc)
    if per_block:
        scale = pad_dim(pad_dim(scale, 0, kp // wbr), 1, np_ // wbc)

    block_m = fit_block(min(block_m, mp), mp, 8)
    block_n = fit_block(min(block_n, np_), np_, wbc)
    block_k = fit_block(min(block_k, kp), kp, unit_k)
    grid = (mp // block_m, np_ // block_n, kp // block_k)

    kern = functools.partial(_kernel, n_bits=n_bits, wbr=wbr, wbc=wbc,
                             block_k=block_k, per_block=per_block)
    scale_spec = pl.BlockSpec((block_k // wbr, block_n // wbc),
                              lambda i, j, kk: (kk, j)) if per_block \
        else pl.BlockSpec((1,), lambda i, j, kk: (0,))
    y = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((n_bits, block_k // 8, block_n),
                         lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((block_k // 8, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((n_bits, block_k // wbr, block_n // wbc),
                         lambda i, j, kk: (0, kk, j)),
            scale_spec,
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(x, planes_packed, sign_packed, mask, scale)
    return y[:m, :n]
