"""Pallas TPU kernel: packed-integer dequant matmul (BWQ deployment path).

After training, BWQ weights are packed to int8 (or int4 nibble pairs) with
a per-WB scale — this is what serving reads from HBM.  The kernel streams
the packed tile, dequantizes in VMEM (nibble unpack + per-block scale
broadcast) and performs a single MXU matmul.  HBM weight traffic drops 2x
(int8) / 4x (int4) vs bf16 — the roofline lever for decode shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel8(x_ref, w_ref, s_ref, o_ref, *, wbr, wbc):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    s = jnp.repeat(jnp.repeat(s_ref[...], wbr, axis=0), wbc, axis=1)
    o_ref[...] += jnp.dot(x, w * s, preferred_element_type=jnp.float32)


def _kernel4(x_ref, w_ref, s_ref, o_ref, *, wbr, wbc, block_k):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    packed = w_ref[...]                                  # (bk//2, bn) uint8
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    w = jnp.stack([lo, hi], axis=1).reshape(block_k, packed.shape[1])
    s = jnp.repeat(jnp.repeat(s_ref[...], wbr, axis=0), wbc, axis=1)
    o_ref[...] += jnp.dot(x, w.astype(jnp.float32) * s,
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "wbr", "wbc", "block_m",
                                             "block_n", "block_k",
                                             "interpret"))
def packed_matmul(x, w_int, scale, *, bits: int = 8, wbr: int = 8,
                  wbc: int = 128, block_m: int = 128, block_n: int = 256,
                  block_k: int = 512, interpret: bool = True):
    """y[M,N] = x[M,K] @ (dequant(w_int) * per-WB scale).

    int8: w_int (K, N) int8.  int4: w_int (K//2, N) uint8 (row 2j low nibble).
    scale: (K//wbr, N//wbc) f32.
    """
    from .bitplane_matmul import _fit
    m, k = x.shape
    n = w_int.shape[-1]
    block_m = _fit(block_m, m, 1)
    block_n = _fit(block_n, n, wbc)
    block_k = _fit(block_k, k, max(2, wbr))
    assert k % block_k == 0 and n % block_n == 0 and m % block_m == 0
    grid = (m // block_m, n // block_n, k // block_k)
    common = dict(
        grid=grid,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )
    s_spec = pl.BlockSpec((block_k // wbr, block_n // wbc),
                          lambda i, j, kk: (kk, j))
    x_spec = pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk))
    if bits == 8:
        kern = functools.partial(_kernel8, wbr=wbr, wbc=wbc)
        w_spec = pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j))
    elif bits == 4:
        kern = functools.partial(_kernel4, wbr=wbr, wbc=wbc, block_k=block_k)
        w_spec = pl.BlockSpec((block_k // 2, block_n),
                              lambda i, j, kk: (kk, j))
    else:
        raise ValueError(bits)
    return pl.pallas_call(kern, in_specs=[x_spec, w_spec, s_spec],
                          **common)(x, w_int, scale)
