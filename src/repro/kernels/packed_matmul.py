"""Pallas TPU kernel: packed-integer dequant matmul (BWQ deployment path).

After training, BWQ weights are packed to int8 (or int4 nibble pairs) with
a per-WB scale — this is what serving reads from HBM.  The kernel streams
the packed tile, dequantizes in VMEM (nibble unpack + per-block scale
broadcast) and performs a single MXU matmul.  HBM weight traffic drops 2x
(int8) / 4x (int4) vs bf16 — the roofline lever for decode shapes.

Geometry is defined by the per-WB scale grid: K = scale.shape[0] * wbr and
N = scale.shape[1] * wbc.  Operands that do not divide the tile sizes are
zero-padded up to tile multiples and the output is trimmed back — this
covers decode-shaped M in 1..16, ragged K/N, and the int4 odd-block-padded
K case (an extra zero WB row absorbs the unpaired nibble).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pallas_utils import fit_block, pad_dim, resolve_interpret, round_up


def _kernel8(x_ref, w_ref, s_ref, o_ref, *, wbr, wbc):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    s = jnp.repeat(jnp.repeat(s_ref[...], wbr, axis=0), wbc, axis=1)
    o_ref[...] += jnp.dot(x, w * s, preferred_element_type=jnp.float32)


def _kernel4(x_ref, w_ref, s_ref, o_ref, *, wbr, wbc, block_k):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    packed = w_ref[...]                                  # (bk//2, bn) uint8
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    w = jnp.stack([lo, hi], axis=1).reshape(block_k, packed.shape[1])
    s = jnp.repeat(jnp.repeat(s_ref[...], wbr, axis=0), wbc, axis=1)
    o_ref[...] += jnp.dot(x, w.astype(jnp.float32) * s,
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "wbr", "wbc", "block_m",
                                             "block_n", "block_k",
                                             "interpret"))
def packed_matmul(x, w_int, scale, *, bits: int = 8, wbr: int = 8,
                  wbc: int = 128, block_m: int = 128, block_n: int = 256,
                  block_k: int = 512, interpret: bool | None = None):
    """y[M, N] = x[M, K] @ (dequant(w_int) * per-WB scale).

    int8: w_int (K, N) int8.  int4: w_int (ceil(K/2), N) uint8 (row 2j in
    the low nibble; an odd K carries one zero pad row in the last byte).
    scale: (K//wbr, N//wbc) f32.  x may have fewer than K columns (the
    unpadded true fan-in); the missing columns multiply zero-padded weight
    rows and are zero-filled here.  ``interpret=None`` auto-selects
    interpret mode off-TPU.
    """
    interpret = resolve_interpret(interpret)
    m, k_x = x.shape
    gr, gc = scale.shape
    k, n = gr * wbr, gc * wbc
    if k_x > k or w_int.shape[-1] != n:
        raise ValueError(f"operand geometry mismatch: x K={k_x}, "
                         f"scale grid K={k} N={n}, w N={w_int.shape[-1]}")

    # pad K up to a tile unit that is both a WB-row multiple and (for int4)
    # an even row count, so nibble unpacking never straddles a tile edge
    unit_k = wbr if (bits == 8 or wbr % 2 == 0) else 2 * wbr
    kp = round_up(k, unit_k)
    mp = round_up(m, 8)            # decode-shaped M (1..16) pads to one tile
    x = pad_dim(pad_dim(x, 1, kp), 0, mp)
    scale = pad_dim(scale, 0, kp // wbr)
    if bits == 8:
        w_int = pad_dim(w_int, 0, kp)
    elif bits == 4:
        w_int = pad_dim(w_int, 0, kp // 2)
    else:
        raise ValueError(bits)

    block_m = fit_block(min(block_m, mp), mp, 8)
    block_n = fit_block(min(block_n, n), n, wbc)
    block_k = fit_block(min(block_k, kp), kp, unit_k)
    grid = (mp // block_m, n // block_n, kp // block_k)
    common = dict(
        grid=grid,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.float32),
        interpret=interpret,
    )
    s_spec = pl.BlockSpec((block_k // wbr, block_n // wbc),
                          lambda i, j, kk: (kk, j))
    x_spec = pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk))
    if bits == 8:
        kern = functools.partial(_kernel8, wbr=wbr, wbc=wbc)
        w_spec = pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j))
    else:
        kern = functools.partial(_kernel4, wbr=wbr, wbc=wbc, block_k=block_k)
        w_spec = pl.BlockSpec((block_k // 2, block_n),
                              lambda i, j, kk: (kk, j))
    y = pl.pallas_call(kern, in_specs=[x_spec, w_spec, s_spec],
                       **common)(x, w_int, scale)
    return y[:m]
