"""Pallas TPU kernel: fused symmetric-PACT clip + uniform quantize.

Elementwise VPU kernel; fusing clip+round+rescale keeps the activation
quantization a single HBM round-trip in front of each quantized matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pallas_utils import fit_block, pad_dim, resolve_interpret, round_up


def _kernel(x_ref, beta_ref, o_ref, *, act_bits: int):
    x = x_ref[...]
    b = jnp.maximum(beta_ref[0], 1e-6).astype(x.dtype)
    levels = jnp.asarray(2 ** (act_bits - 1) - 1, x.dtype)
    y = jnp.clip(x, -b, b)
    o_ref[...] = jnp.round(y / b * levels) * (b / levels)


@functools.partial(jax.jit, static_argnames=("act_bits", "block_rows",
                                             "interpret"))
def pact_quant_pallas(x, beta, *, act_bits: int = 8, block_rows: int = 256,
                      interpret: bool | None = None):
    """x: (R, C) any float dtype; beta: (1,) clip level.

    Rows that do not divide ``block_rows`` are padded and trimmed back;
    ``interpret=None`` auto-selects interpret mode off-TPU."""
    interpret = resolve_interpret(interpret)
    r, c = x.shape
    rp = round_up(r, 8)
    block_rows = fit_block(min(block_rows, rp), rp, 8)
    x = pad_dim(x, 0, rp)
    y = pl.pallas_call(
        functools.partial(_kernel, act_bits=act_bits),
        grid=(rp // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, c), x.dtype),
        interpret=interpret,
    )(x, beta)
    return y[:r]
