"""Pallas TPU kernels for BWQ inference (validated in interpret mode).

bitplane_matmul — bit-plane-sliced mixed-precision matmul (paper layout)
packed_matmul   — int8/int4 per-WB-scale dequant matmul (deployment)
pact_quant      — fused symmetric PACT clip + quantize
paged_attention — fused paged decode attention with in-kernel KV dequant
"""
from .bitplane_matmul import bitplane_matmul
from .packed_matmul import packed_matmul
from .pact_kernel import pact_quant_pallas
from .paged_attention import paged_attention
from .pallas_utils import default_interpret, resolve_interpret
from .ops import (BitplaneLayout, PackedLayout, bwq_dense_bitplane,
                  bwq_dense_packed, to_bitplane_layout, to_packed_layout)
from . import ref
