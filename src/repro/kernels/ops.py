"""High-level jitted wrappers: QuantizedTensor -> kernel-ready layouts.

``to_bitplane_layout`` / ``to_packed_layout`` convert a trained
QuantizedTensor (after requantization) into the deployment tensors the
Pallas kernels consume; ``bwq_dense_*`` are drop-in y = x @ W ops.

PackedLayout / ServingWeight contract
-------------------------------------
:class:`PackedLayout` is the kernel-facing view of one packed matrix and
:class:`repro.serve.deploy.ServingWeight` is the same wire format carried
inside a model param tree (plus the true, unpadded ``shape``).  Both obey:

* geometry comes from the per-WB ``scale`` grid — Kp = GR * wbr rows and
  Np = GC * wbc cols of *block-padded* weight; a ServingWeight's true
  (K, N) = ``shape[-2:]`` satisfies K <= Kp, N <= Np and the padded tail
  is exact zeros;
* ``w_int`` stores int8 rows directly, or int4 two's-complement nibble
  pairs packed along K (row 2j in the low nibble).  An odd Kp packs one
  trailing zero row so ``w_int`` has ceil(Kp/2) byte rows;
* ``scale`` is the per-WB *effective* scale: blocks whose live bit-width
  exceeds the container are power-of-two rescaled at pack time with the
  factor folded into their scale entry, so ``dequant = w_int * scale``
  reproduces every block at its own effective bit-width exactly (BWQ's
  mixed precision stays visible to the kernel — nothing is flattened to
  uniform int8);
* dequantization is therefore always ``expand_block_map(scale) * w_int``
  followed by trimming to the true (K, N).

``serve.deploy.serving_to_packed_layout`` adapts a ServingWeight leaf to a
PackedLayout with no copy; ``models.common.qmatmul`` is the call site that
routes model matmuls here.  The plane-sliced serving wire format
(``serve.deploy.BitplaneServingWeight`` -> :class:`BitplaneLayout` via
``serving_to_bitplane_layout``) obeys the same scale-grid geometry, with
a per-WB *effective* scale LUT instead of the per-layer scalar and K
byte-padded up to a multiple of 8 for the 1-bit packing.

Contract appendix — the statically checkable rules
--------------------------------------------------
``repro.analysis.contracts.validate_serving_tree`` enforces the above
declaratively at engine construction and deploy time; each rule id below
is what its path-qualified findings cite (see README "Static analysis &
lint"):

* ``SW1`` — ``scale`` is (..., GR, GC): the per-WB grid IS the geometry.
* ``SW2`` — the grid is the *minimal* block cover of the true shape:
  K <= GR*wbr < K + wbr and N <= GC*wbc < N + wbc.
* ``SW3`` — layer-stack dims LEAD every tensor (scan-sliceable; the QAT
  ``QuantizedTensor`` whose bit axis leads is NOT a serving layout).
* ``SW4`` — payload dtype/shape per precision: bits=8 -> int8
  (..., Kp, Np); bits=4 -> uint8 (..., ceil(Kp/2), Np) nibble pairs,
  and an odd Kp's high pad nibble is exact zeros.
* ``BP1`` — ``planes`` (..., bits, Kp8//8, Np) and ``sign``
  (..., Kp8//8, Np) uint8 with Kp8 = ceil(Kp/8)*8; byte-pad rows are
  zeros (the byte-boundary mirror of SW4's nibble rule).
* ``BP2`` — ``mask`` is (..., bits, GR, GC) f32, binary, and
  prefix-monotone along the bit axis: block occupancy is its
  min(bw, bits) LOW planes — exactly the OU occupancy
  ``weight_stream_bytes`` bills.
* ``BP3`` — ``scale`` LUT is f32 and finite (it pre-folds /(2^n - 1)
  and each block's power-of-two container rescale, so a NaN/inf here
  silently poisons every dequant).
* ``PC1``-``PC3`` — paged decode caches: pool leaves agree on
  (stack, n_pages, page_size), block tables are integer
  (stack, n_slots, nb) with every id inside the pool (``PC2`` flags
  orphaned ids and un-refcounted page sharing), and quantized pools
  carry their per-token scale leaves.
* ``PA1``-``PA3`` — fused paged-attention invariants: k/v pools agree
  on dtype/shape and carry float32 scales matching the payload's
  (stack, n_pages, page, KV) prefix (``PA1``); the pool holds the
  reserved trash page 0 plus >= 1 allocatable page and >= 1 block per
  slot row (``PA2``); a slot's live pages are a contiguous prefix of
  its table row — the kernel walks blocks in order and the fill level
  masks only the trash tail (``PA3``).
* ``PX1``-``PX3`` — live-scheduler ledger invariants
  (``analysis.contracts.validate_scheduler``): prefix-cache refcounts
  equal the live slots aliasing each shared page and the allocator's
  in-use count closes against slot + cache ownership (``PX1``, so a
  parked snapshot holds no pool pages); every slot's write frontier
  sits at or past its shared-prefix region — shared pages are
  read-only (``PX2``); free/parked block-table rows are all zeros and
  live rows mirror the host ledger exactly (``PX3``).
* ``AT1`` — an autotuned assignment respects its byte budget exactly:
  ``weight_stream_bytes(tree) <= budget`` under the same occupancy
  accounting the allocator optimized against (no double bookkeeping).
* ``AT2`` — a speculative draft tree is a pure mask-truncation view of
  the deployed tree: payload tensors (planes/sign/scale) are shared,
  and each block's draft mask keeps exactly its ``min(k, occ)`` HIGHEST
  live planes — a contiguous top run of the deployed prefix, so the
  draft reads a strict subset of the bytes the verify pass streams.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from ..core.bitrep import QuantizedTensor, compose_int, _levels
from .bitplane_matmul import bitplane_matmul
from .packed_matmul import packed_matmul
from .ref import pack_bits


class BitplaneLayout(NamedTuple):
    planes_packed: jnp.ndarray   # (n, K//8, N) uint8
    sign_packed: jnp.ndarray     # (K//8, N) uint8
    mask: jnp.ndarray            # (n, K//wbr, N//wbc) f32
    scale: jnp.ndarray           # (1,) per-layer OR (K//wbr, N//wbc) per-WB
    n_bits: int
    wbr: int
    wbc: int


class PackedLayout(NamedTuple):
    w_int: jnp.ndarray           # int8 (K,N) or uint8 (K//2, N) nibbles
    scale: jnp.ndarray           # (K//wbr, N//wbc)
    bits: int
    wbr: int
    wbc: int


def to_bitplane_layout(qt: QuantizedTensor) -> BitplaneLayout:
    """Requires a TPU-aligned spec (wb_rows multiple-of-8-compatible: K%8==0)."""
    assert qt.planes.ndim == 3, "single matrix expected"
    q = jnp.clip(jnp.round(compose_int(qt)), 0, _levels(qt.n_bits))
    q = q.astype(jnp.int32)
    planes = jnp.stack([((q >> b) & 1).astype(jnp.uint8)
                        for b in range(qt.n_bits)])
    planes_packed = pack_bits(planes)
    sign_bits = (qt.sign < 0).astype(jnp.uint8)
    sign_packed = pack_bits(sign_bits[None])[0]
    scale = jnp.reshape(qt.scale.astype(jnp.float32), (1,))
    return BitplaneLayout(planes_packed, sign_packed,
                          qt.mask.astype(jnp.float32), scale, qt.n_bits,
                          qt.spec.wb_rows, qt.spec.wb_cols)


def to_packed_layout(qt: QuantizedTensor, bits: int = 8) -> PackedLayout:
    """Per-WB scale folded so each block uses its own bitwidth ceiling.

    A WB with bitwidth bw stores magnitudes in [0, 2^bw-1]; rescaling by
    2^(n-bw) maps them onto the shared int grid without precision loss when
    bw <= bits-1 (sign takes one bit in two's complement).
    """
    q = jnp.clip(jnp.round(compose_int(qt)), 0, _levels(qt.n_bits))
    signed = qt.sign * q                                  # (K, N)
    spec = qt.spec
    gscale = qt.scale.astype(jnp.float32) / _levels(qt.n_bits)
    gr, gc = qt.mask.shape[-2], qt.mask.shape[-1]
    block_scale = jnp.broadcast_to(jnp.reshape(gscale, (1, 1)), (gr, gc))
    # Blocks whose live bit-width exceeds the container (bits-1 magnitude
    # bits after the sign) are rescaled by a power of two: exact whenever
    # bw <= bits-1, drops (bw - bits + 1) LSBs otherwise.
    from ..core.blocking import expand_block_map
    bw = jnp.sum(qt.mask, axis=0)                         # (GR, GC)
    shift = jnp.maximum(bw - float(bits - 1), 0.0)
    factor = 2.0 ** shift
    f_full = expand_block_map(factor, spec)
    lim = 2 ** (bits - 1)
    wq = jnp.clip(jnp.round(signed / f_full), -lim, lim - 1).astype(jnp.int32)
    if bits == 8:
        return PackedLayout(wq.astype(jnp.int8), block_scale * factor, 8,
                            spec.wb_rows, spec.wb_cols)
    if bits == 4:
        lo = wq[0::2] & 0xF
        hi = wq[1::2] & 0xF
        packed = (lo | (hi << 4)).astype(jnp.uint8)
        return PackedLayout(packed, block_scale * factor, 4,
                            spec.wb_rows, spec.wb_cols)
    raise ValueError(bits)


def truncate_mask_topk(mask: jnp.ndarray, k: int) -> jnp.ndarray:
    """Draft-model view of a BP2 mask LUT: keep each block's top-k planes.

    ``mask`` is (..., bits, GR, GC) binary f32, prefix-monotone along the
    bit axis (a block with occupancy ``o`` keeps planes ``0..o-1``).  The
    returned LUT keeps planes ``max(o-k, 0)..o-1`` — the k *highest* live
    planes — so composing the same payload through it floors away the low
    ``o-k`` magnitude bits: a coarser read of identical bytes, which is
    what makes bitplane truncation a free draft model.  The result is NOT
    prefix-monotone (it deliberately zeroes low planes), so draft trees
    bypass BP2 validation and are checked by the AT2 contract instead.
    Zero-cost at trace time: the kernel multiplies planes by the mask, so
    ``bitplane_matmul`` consumes the truncated LUT unchanged.
    """
    if k < 0:
        raise ValueError(f"k must be >= 0, got {k}")
    bits = mask.shape[-3]
    occ = jnp.sum(mask, axis=-3, keepdims=True)       # (..., 1, GR, GC)
    idx = jnp.arange(bits, dtype=mask.dtype).reshape((bits, 1, 1))
    return mask * (idx >= occ - float(k)).astype(mask.dtype)


def bwq_dense_bitplane(x, layout: BitplaneLayout,
                       interpret: bool | None = None):
    """y = x @ W from the bit-plane layout (interpret auto-detected)."""
    return bitplane_matmul(x, layout.planes_packed, layout.sign_packed,
                           layout.mask, layout.scale, n_bits=layout.n_bits,
                           wbr=layout.wbr, wbc=layout.wbc,
                           interpret=interpret)


def bwq_dense_packed(x, layout: PackedLayout, interpret: bool | None = None):
    """y = x @ W from the packed-integer layout (interpret auto-detected)."""
    return packed_matmul(x, layout.w_int, layout.scale, bits=layout.bits,
                         wbr=layout.wbr, wbc=layout.wbc, interpret=interpret)
