"""Pallas TPU kernel: fused paged decode attention over a quantized page
pool (the serving hot path).

The gather fallback in ``models.attention`` pays O(max_len) per decode
step twice: ``paged_gather`` materializes a contiguous (B, T, ...) int
view of every slot's pages, then ``dequantize_kv`` materializes the f32
K/V tree — before a single score is computed.  This kernel walks each
slot's block table *inside the grid* instead: scalar-prefetched table
entries drive the page index maps, so exactly one pool page per grid
step lands in VMEM, is dequantized there (int8 / nibble-packed int4 with
per-token scales), and feeds the flash-attention running (max, denom,
acc) accumulation.  Neither the contiguous KV view nor the f32 KV tree
ever exists; HBM traffic per step is the *quantized* bytes of the pages
a slot actually fills.

Grid: (B, KV // block_kv, nb).  The last axis iterates a slot's blocks
in order, revisiting the output block with running rescaling; positions
at or past the slot's fill level are masked, which is also what keeps
the reserved trash page (page 0 — where unallocated table entries point)
inert.  GQA queries arrive pre-grouped as (B, KV, G, dh).  ``block_kv``
(KV heads per grid cell) is the kernel's tile parameter — see
``benchmarks/hillclimb.py`` for the real-TPU sweep.

``interpret=None`` auto-selects interpret mode off-TPU (pallas_utils),
so CPU tests and CI exercise the same program.  Compiled TPU use wants a
lane-aligned head dim; the wrapper never pads the pool leaves (a pad
would be the per-step O(pool) copy this kernel exists to delete).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_utils import fit_block, resolve_interpret

NEG_INF = -2.0e38


def _dequant(raw, scale_ref, bits: int):
    """(page, bkv, dh_s) stored page -> (page, bkv, dh) f32, in VMEM."""
    if bits == 4:
        lo = (raw & 0xF).astype(jnp.int32)
        hi = ((raw >> 4) & 0xF).astype(jnp.int32)
        lo = jnp.where(lo >= 8, lo - 16, lo)
        hi = jnp.where(hi >= 8, hi - 16, hi)
        # pack_int4 puts even head positions in the low nibble: interleave
        x = jnp.stack([lo, hi], axis=-1).reshape(*raw.shape[:-1],
                                                 raw.shape[-1] * 2)
        x = x.astype(jnp.float32)
    else:
        x = raw.astype(jnp.float32)
    if scale_ref is not None:
        x = x * scale_ref[0].astype(jnp.float32)[..., None]
    return x


def _decode_kernel(table_ref, len_ref, win_ref,      # scalar prefetch
                   q_ref, *rest, bits: int, page: int, softcap: float):
    quantized = bits < 32
    if quantized:
        k_ref, ks_ref, v_ref, vs_ref, o_ref, m_ref, l_ref = rest
    else:
        k_ref, v_ref, o_ref, m_ref, l_ref = rest
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    j = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0].astype(jnp.float32)                 # (bkv, g, dh)
    k = _dequant(k_ref[0], ks_ref, bits)             # (page, bkv, dh)
    dh = q.shape[-1]
    # batched over the kv-head tile: (bkv, g, dh) x (page, bkv, dh)
    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)          # (bkv, g, page)
    s = s * (1.0 / math.sqrt(dh))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
    ln = len_ref[b]
    valid = pos < ln                                 # per-slot fill level
    w = win_ref[0]
    valid &= jnp.where(w > 0, pos >= ln - w, True)   # sliding window
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]          # (bkv, g)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])                # (bkv, g, page)
    corr = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1)
    v = _dequant(v_ref[0], vs_ref, bits)             # (page, bkv, dh)
    pv = jax.lax.dot_general(
        p, v, dimension_numbers=(((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)          # (bkv, g, dh)
    o_ref[0] = o_ref[0] * corr[..., None] + pv

    @pl.when(j == nb - 1)
    def _finish():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[...], 1e-30)[..., None]


@functools.partial(jax.jit, static_argnames=("softcap", "block_kv",
                                             "interpret"))
def paged_attention(q, k_pages, v_pages, k_scale, v_scale, table, kv_len,
                    *, window=None, softcap: float = 0.0,
                    block_kv: int = 1, interpret: bool | None = None):
    """Decode attention straight over a (quantized) page pool.

    q:        (B, KV, G, dh) grouped queries (one decode token per slot).
    k/v:      (P, page, KV, dh) int8 or f32, or (P, page, KV, dh//2)
              uint8 nibble pairs (``core.quantize.pack_int4`` layout).
    k/v_scale: (P, page, KV) f32 per-token/head scales (None when f32).
    table:    (B, nb) int32 block table; page 0 is the reserved trash
              page, live blocks are a contiguous per-row prefix (PA2).
    kv_len:   (B,) int32 fill levels; position ``kv_len - 1`` is the
              decode token itself, so causality == the fill mask.
    window:   optional ()-shaped int (or Python int): > 0 restricts
              attention to the last ``window`` positions.

    Returns (B, KV, G, dh) f32.  ``block_kv`` tiles KV heads per grid
    cell (largest divisor of KV <= block_kv is used).
    """
    interpret = resolve_interpret(interpret)
    b, kv, g, dh = q.shape
    p_pages, page = k_pages.shape[0], k_pages.shape[1]
    nb = table.shape[1]
    bits = {jnp.dtype(jnp.int8): 8, jnp.dtype(jnp.uint8): 4}.get(
        jnp.dtype(k_pages.dtype), 32)
    dh_s = k_pages.shape[-1]
    if bits == 4 and dh_s * 2 != dh:
        raise ValueError(f"int4 pool head dim {dh_s}*2 != query dh {dh}")
    if bits != 4 and dh_s != dh:
        raise ValueError(f"pool head dim {dh_s} != query dh {dh}")
    quantized = bits < 32

    q = q.astype(jnp.float32)
    table = jnp.asarray(table, jnp.int32)
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(b)
    win = jnp.asarray(0 if window is None else window,
                      jnp.int32).reshape(1)
    block_kv = fit_block(min(block_kv, kv), kv, 1)
    grid = (b, kv // block_kv, nb)

    def at_qo(bi, hi, ji, tab, ln, wn):
        return (bi, hi, 0, 0)

    def at_page(bi, hi, ji, tab, ln, wn):
        return (tab[bi, ji], 0, hi, 0)

    def at_scale(bi, hi, ji, tab, ln, wn):
        return (tab[bi, ji], 0, hi)

    q_spec = pl.BlockSpec((1, block_kv, g, dh), at_qo)
    kv_spec = pl.BlockSpec((1, page, block_kv, dh_s), at_page)
    sc_spec = pl.BlockSpec((1, page, block_kv), at_scale)
    in_specs = [q_spec, kv_spec] + ([sc_spec] if quantized else []) \
        + [kv_spec] + ([sc_spec] if quantized else [])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_kv, g, dh), at_qo),
        scratch_shapes=[pltpu.VMEM((block_kv, g), jnp.float32),
                        pltpu.VMEM((block_kv, g), jnp.float32)])
    kern = functools.partial(_decode_kernel, bits=bits, page=page,
                             softcap=float(softcap))
    operands = (table, kv_len, win, q, k_pages) \
        + ((k_scale,) if quantized else ()) + (v_pages,) \
        + ((v_scale,) if quantized else ())
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, dh), jnp.float32),
        interpret=interpret)(*operands)
