"""Pure-jnp oracles for every kernel (the allclose targets).

Layouts (TPU-native WB geometry — see DESIGN.md §2; wb_rows=8, wb_cols=128
by default so block boundaries align with sublanes/lanes):

* bit-plane: ``planes_packed`` (n, K//8, N) uint8, bit r of byte j = plane
  value at row 8j+r; ``sign_packed`` (K//8, N) uint8 (1 = negative);
  ``mask`` (n, K//wbr, N//wbc) {0,1}; ``scale`` () per-layer.
* packed-int: ``w_int`` int8 (K, N) signed magnitudes (int8 mode) or
  (K//2, N) uint8 two nibbles (int4 mode, row 2j in low nibble);
  ``scale`` (K//wbr, N//wbc) per-WB.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def unpack_bits(packed: jnp.ndarray) -> jnp.ndarray:
    """(..., K//8, N) uint8 -> (..., K, N) {0,1} float32 (row-major bits)."""
    bits = [(packed >> r) & 1 for r in range(8)]
    x = jnp.stack(bits, axis=-2)                   # (..., K//8, 8, N)
    shape = x.shape[:-3] + (x.shape[-3] * 8, x.shape[-1])
    return x.reshape(shape).astype(jnp.float32)


def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """(..., K, N) {0,1} -> (..., K//8, N) uint8."""
    k = bits.shape[-2]
    x = bits.reshape(*bits.shape[:-2], k // 8, 8, bits.shape[-1])
    x = x.astype(jnp.uint8)
    out = jnp.zeros(x.shape[:-2] + (x.shape[-1],), jnp.uint8)
    for r in range(8):
        out = out | (x[..., r, :] << r)
    return out


def expand_mask(mask: jnp.ndarray, wbr: int, wbc: int) -> jnp.ndarray:
    m = jnp.repeat(mask, wbr, axis=-2)
    return jnp.repeat(m, wbc, axis=-1)


def bitplane_matmul_ref(x, planes_packed, sign_packed, mask, scale,
                        wbr: int = 8, wbc: int = 128,
                        out_dtype=jnp.float32) -> jnp.ndarray:
    """y = x @ W, W = (1-2*sign) * scale/(2^n -1) * sum_b 2^b plane_b*mask_b.

    ``scale``: scalar per-layer (divided by ``2^n - 1`` here) or a 2-D
    (K//wbr, N//wbc) per-WB *effective* scale LUT (serving layout, applied
    as-is — the /(2^n-1) and per-block rescale factors are pre-folded).
    ``planes_packed`` may pack beyond the K//wbr WB rows up to a byte
    boundary (odd block-padded K); the surplus rows are trimmed, and ``x``
    with fewer than K columns is zero-filled like the packed oracle."""
    n = planes_packed.shape[0]
    planes = unpack_bits(planes_packed)            # (n, K8, N)
    sign = 1.0 - 2.0 * unpack_bits(sign_packed)    # (K8, N) in {+1,-1}
    m = jax.vmap(lambda mm: expand_mask(mm, wbr, wbc))(mask)
    kp = m.shape[-2]
    if planes.shape[-2] > kp:      # byte-pad rows beyond the WB grid
        planes = planes[..., :kp, :]
        sign = sign[:kp, :]
    weights = (2.0 ** jnp.arange(n, dtype=jnp.float32))
    mag = jnp.tensordot(weights, planes * m, axes=(0, 0))
    if jnp.ndim(scale) == 2:
        w = sign * mag * expand_mask(scale, wbr, wbc)
    else:
        w = sign * mag * (scale / (2.0 ** n - 1.0))
    if x.shape[-1] < w.shape[0]:
        x = jnp.pad(x, ((0, 0), (0, w.shape[0] - x.shape[-1])))
    return (x.astype(jnp.float32) @ w).astype(out_dtype)


def packed_matmul_ref(x, w_int, scale, bits: int = 8,
                      wbr: int = 8, wbc: int = 128,
                      out_dtype=jnp.float32) -> jnp.ndarray:
    """y = x @ (dequant(w_int) * per-block scale).

    Shares the kernel's geometry contract: K/N come from the scale grid;
    an int4 odd block-padded K carries one zero pad row in the last byte
    (trimmed here), and x may have fewer than K columns (zero-filled)."""
    if bits == 8:
        w = w_int.astype(jnp.float32)
    elif bits == 4:
        lo = (w_int & 0xF).astype(jnp.int8)
        hi = ((w_int >> 4) & 0xF).astype(jnp.int8)
        # two's-complement nibbles in [-8, 7]
        lo = jnp.where(lo >= 8, lo - 16, lo).astype(jnp.float32)
        hi = jnp.where(hi >= 8, hi - 16, hi).astype(jnp.float32)
        k2, n_ = w_int.shape
        w = jnp.stack([lo, hi], axis=1).reshape(2 * k2, n_)
    else:
        raise ValueError(bits)
    s = expand_mask(scale, wbr, wbc)
    w = w[:s.shape[0]]
    if x.shape[-1] < s.shape[0]:
        x = jnp.pad(x, ((0, 0), (0, s.shape[0] - x.shape[-1])))
    return (x.astype(jnp.float32) @ (w * s)).astype(out_dtype)


def _unpack_nibbles(u: jnp.ndarray) -> jnp.ndarray:
    """(..., dh//2) uint8 nibble pairs -> (..., dh) int32 in [-8, 7]
    (``core.quantize.pack_int4`` layout: even positions in the low
    nibble)."""
    lo = (u & 0xF).astype(jnp.int32)
    hi = ((u >> 4) & 0xF).astype(jnp.int32)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    return jnp.stack([lo, hi], axis=-1).reshape(*u.shape[:-1],
                                                u.shape[-1] * 2)


def paged_attention_ref(q, k_pages, v_pages, k_scale, v_scale, table,
                        kv_len, *, window=None,
                        softcap: float = 0.0) -> jnp.ndarray:
    """Gather-then-softmax oracle for ``kernels.paged_attention``.

    Same contract as the kernel (q (B, KV, G, dh); pool leaves
    (P, page, KV, dh|dh//2); per-token scales or None; (B, nb) table;
    (B,) fill levels) — but it materializes the contiguous (B, T, ...)
    view and the f32 KV tree the kernel exists to avoid, so it is the
    allclose target, never the hot path."""
    b, kv, g, dh = q.shape
    neg_inf = -2.0e38

    def gather(leaf):
        x = jnp.take(leaf, table, axis=0)
        return x.reshape(b, -1, *leaf.shape[2:])     # (B, nb*page, ...)

    k, v = gather(k_pages), gather(v_pages)
    if k.dtype == jnp.uint8:                         # nibble-packed int4
        k, v = _unpack_nibbles(k), _unpack_nibbles(v)
    if k_scale is not None:
        k = k.astype(jnp.float32) * gather(k_scale)[..., None]
        v = v.astype(jnp.float32) * gather(v_scale)[..., None]
    else:
        k, v = k.astype(jnp.float32), v.astype(jnp.float32)
    t = k.shape[1]
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32), k,
                   preferred_element_type=jnp.float32) / jnp.sqrt(float(dh))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(t, dtype=jnp.int32)[None, :]
    ln = jnp.asarray(kv_len, jnp.int32)[:, None]
    valid = pos < ln
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        valid &= jnp.where(w > 0, pos >= ln - w, True)
    s = jnp.where(valid[:, None, None, :], s, neg_inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgt,btkd->bkgd", p, v)


def pact_quant_ref(x, beta, act_bits: int) -> jnp.ndarray:
    """Symmetric PACT clip + uniform quantization (forward only)."""
    levels = float(2 ** (act_bits - 1) - 1)
    b = jnp.maximum(beta, 1e-6)
    y = jnp.clip(x, -b, b)
    return (jnp.round(y / b * levels) * (b / levels)).astype(x.dtype)
