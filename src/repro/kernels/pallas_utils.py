"""Shared helpers for the Pallas kernels: platform-aware interpret default
and pad-and-trim tiling geometry.

Every kernel entry point takes ``interpret=None`` and resolves it here, so
the same call site runs compiled on TPU and interpreted everywhere else
(CPU CI, tests, notebooks) without the caller threading a platform flag.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def default_interpret() -> bool:
    """Interpret Pallas kernels everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def round_up(x: int, multiple: int) -> int:
    return -(-x // multiple) * multiple


def fit_block(pref: int, total: int, multiple: int) -> int:
    """Largest block <= pref that divides total and is a multiple-multiple.

    ``total`` must itself be a multiple of ``multiple`` (the pad-and-trim
    wrappers guarantee this), so a valid block always exists.
    """
    best = multiple
    d = multiple
    while d <= min(pref, total):
        if total % d == 0:
            best = d
        d += multiple
    return best


def pad_dim(a: jnp.ndarray, axis: int, target: int) -> jnp.ndarray:
    """Zero-pad ``a`` along ``axis`` up to length ``target`` (no-op if
    already there)."""
    cur = a.shape[axis]
    if cur == target:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, target - cur)
    return jnp.pad(a, pad)
