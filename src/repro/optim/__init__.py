from .optimizers import Optimizer, adamw, sgd, cosine_schedule, global_norm
from .grad_compress import (compress_decompress, compressed_psum,
                            init_error_state)
