"""Error-feedback int8 gradient compression for cross-pod data parallelism.

At 1000+ node scale the pod axis reduces over DCN, not ICI; int8 compression
cuts that traffic 4x.  ``compress_decompress`` is the error-feedback
quantizer (per-leaf scale, residual carried across steps — convergence-safe);
``compressed_psum`` demonstrates the actual collective under shard_map for
tests / the launcher's --grad-compress flag.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_state(grads: Any) -> Any:
    return jax.tree_util.tree_map(jnp.zeros_like, grads)


def _quant_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(grads: Any, err: Any) -> Tuple[Any, Any]:
    """Quantize (g + err) to int8, return (dequantized, new_err)."""
    def one(g, e):
        x = g + e
        q, s = _quant_int8(x)
        deq = q.astype(g.dtype) * s
        return deq, x - deq

    flat = jax.tree_util.tree_map(one, grads, err)
    deq = jax.tree_util.tree_map(lambda t: t[0], flat,
                                 is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree_util.tree_map(lambda t: t[1], flat,
                                     is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_err


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-quantized psum (inside shard_map): quantize locally, reduce the
    int values (int32 accumulate), rescale by the max participating scale."""
    q, s = _quant_int8(x)
    s_max = jax.lax.pmax(s, axis_name)
    # requantize against the shared scale so the sum is consistent
    q2 = jnp.clip(jnp.round(x / s_max), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q2, axis_name)
    return total.astype(x.dtype) * s_max
