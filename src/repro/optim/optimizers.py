"""Minimal, pytree-generic optimizers (SGD+momentum, AdamW) + schedules.

Works directly on parameter trees containing QuantizedTensor /
FakeQuantTensor nodes: updates apply to every float array leaf; the train
step zeroes the gradients of frozen quantization metadata (mask, sign,
bitwidth, scale) before calling in, so no special-casing is needed here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves) + 1e-20)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, opt_state, params, lr) -> (new_params, new_opt_state)


def sgd(momentum: float = 0.9, weight_decay: float = 1e-4,
        nesterov: bool = False, grad_clip: float = 0.0) -> Optimizer:
    def init(params):
        return {"mu": _tmap(jnp.zeros_like, params)}

    def update(grads, state, params, lr):
        if grad_clip:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip / (gn + 1e-9))
            grads = _tmap(lambda g: g * scale, grads)
        if weight_decay:
            grads = _tmap(lambda g, p: g + weight_decay * p, grads, params)
        mu = _tmap(lambda m, g: momentum * m + g, state["mu"], grads)
        step_dir = _tmap(lambda m, g: momentum * m + g, mu, grads) \
            if nesterov else mu
        new_params = _tmap(lambda p, d: p - lr * d, params, step_dir)
        return new_params, {"mu": mu}

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, grad_clip: float = 1.0) -> Optimizer:
    def init(params):
        return {"m": _tmap(jnp.zeros_like, params),
                "v": _tmap(jnp.zeros_like, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        if grad_clip:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip / (gn + 1e-9))
            grads = _tmap(lambda g: g * scale, grads)
        t = state["t"] + 1
        m = _tmap(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        v = _tmap(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m_, v_):
            step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            return p - lr * (step + weight_decay * p)

        new_params = _tmap(upd, params, m, v)
        return new_params, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def cosine_schedule(base_lr: float, total_steps: int,
                    warmup: int = 0, min_frac: float = 0.0):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, step / jnp.maximum(warmup, 1))
        prog = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * warm * (min_frac + (1 - min_frac) * cos)
    return lr
