"""Compile-shape footprint: enumerate every (function, token-block shape)
signature a serving workload will compile, statically.

XLA compiles one program per distinct input signature.  The scheduler was
designed so steady-state serving compiles O(1) programs (decode is always
``(n_slots, 1)``; chunked prefill pads the final chunk to the chunk
width), but the monolithic insertion paths compile per distinct prompt
width — a workload with 40 distinct widths silently compiles 40 prefill
programs.  This pass mirrors the scheduler's shape decisions
(:meth:`Scheduler._plan_chunks`, the legacy lazy-init broadcast,
``generate``'s 64-rounded headroom) as pure arithmetic, so a recompile
blowup is a lint failure with a census, not a latency mystery.

``chunk_widths`` must stay in lockstep with ``Scheduler._plan_chunks`` —
tests/test_analysis.py cross-checks them chunk-for-chunk.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .report import Finding


@dataclasses.dataclass(frozen=True)
class CompileSig:
    """One distinct jit signature: entry point + token-block shape."""
    fn: str                    # 'prefill' | 'prefill_at' | 'chunk' | 'decode'
    shape: Tuple[int, ...]     # token block (B, W)
    static: Tuple = ()         # static args baked into the trace (extra_slots)

    def format(self) -> str:
        s = f" static={self.static}" if self.static else ""
        return f"{self.fn}{list(self.shape)}{s}"


def _roundup64(n: int) -> int:
    return -(-n // 64) * 64


def chunk_widths(p: int, chunk: int, total_len: int,
                 vision_tokens: int = 0,
                 family: str = "decoder") -> List[Tuple[int, int]]:
    """(width, start) of every insertion chunk for a ``p``-token prompt.

    Pure mirror of ``Scheduler._plan_chunks``: recurrent-state families
    (ssm/hybrid) and prompts at most one chunk wide insert monolithic; the
    final chunk of a longer prompt is padded to the chunk width, clamped
    to the slot's remaining cache extent."""
    tv = vision_tokens
    if chunk <= 0 or p <= chunk or family in ("ssm", "hybrid"):
        return [(p, 0)]
    out = []
    n_c = -(-p // chunk)
    for c in range(n_c):
        lo, hi = c * chunk, min((c + 1) * chunk, p)
        w = hi - lo
        if c == n_c - 1 and w < chunk:
            w = min(chunk, total_len - (tv + lo))
        out.append((w, 0 if c == 0 else tv + lo))
    return out


def serve_signatures(prompt_widths: Sequence[int], max_new: int,
                     n_slots: int, max_len: Optional[int] = None,
                     page_size: int = 0, prefill_chunk: int = 0,
                     vision_tokens: int = 0,
                     family: str = "decoder") -> List[CompileSig]:
    """Distinct compile signatures for a scheduler run over prompts of the
    given token widths (``prompt_widths`` excludes the vision prefix,
    mirroring ``batch['tokens'].shape[1]``)."""
    if max_len is None:
        max_len = max(p + vision_tokens + _roundup64(max_new)
                      for p in prompt_widths)
    total_len = (-(-max_len // page_size) * page_size if page_size > 0
                 else max_len)
    sigs = {CompileSig("decode", (n_slots, 1))}
    insert_path = page_size > 0 or prefill_chunk > 0
    for p in sorted(set(prompt_widths)):
        if insert_path:
            for w, _start in chunk_widths(p, prefill_chunk, total_len,
                                          vision_tokens, family):
                sigs.add(CompileSig("chunk", (1, w)))
        else:
            pw = p + vision_tokens
            # lazy-init first admission prefills at full cache width
            sigs.add(CompileSig("prefill", (1, p),
                                static=(max_len - pw,)))
            sigs.add(CompileSig("prefill_at", (1, p)))
    return sorted(sigs, key=lambda s: (s.fn, s.shape, s.static))


def generate_signatures(batch: int, prompt_width: int,
                        max_new: int) -> List[CompileSig]:
    """Signatures of the one-shot ``ServeEngine.generate`` path."""
    return [CompileSig("prefill", (batch, prompt_width),
                       static=(_roundup64(max_new),)),
            CompileSig("decode", (batch, 1))]


def footprint_findings(sigs: Sequence[CompileSig],
                       budget: int = 8) -> List[Finding]:
    """Lint the signature census against a compile budget."""
    by_fn: Dict[str, int] = {}
    for s in sigs:
        by_fn[s.fn] = by_fn.get(s.fn, 0) + 1
    census = ", ".join(s.format() for s in sigs)
    findings = [Finding(
        severity="info", pass_name="footprint", rule="census",
        path="scheduler",
        message=f"{len(sigs)} compile signature(s): {census}")]
    if len(sigs) > budget:
        worst = max(by_fn, key=lambda k: by_fn[k])
        findings.append(Finding(
            severity="error", pass_name="footprint", rule="recompile-blowup",
            path=f"scheduler:{worst}",
            message=f"{len(sigs)} distinct compile signatures exceed the "
                    f"budget of {budget} ({worst} alone compiles "
                    f"{by_fn[worst]} programs); chunk prefill "
                    f"(prefill_chunk>0) or bucket prompt widths"))
    return findings


def scheduler_footprint(sched: Any,
                        prompt_widths: Optional[Sequence[int]] = None
                        ) -> List[CompileSig]:
    """Signature census for a live :class:`~repro.serve.scheduler.Scheduler`.

    ``prompt_widths`` defaults to the widths of everything submitted
    (waiting + live slots + finished)."""
    if prompt_widths is None:
        reqs = list(sched.waiting) + \
            [s.req for s in sched.slots if s is not None]
        prompt_widths = [r.inputs["tokens"].shape[1] for r in reqs]
        if not prompt_widths:
            prompt_widths = [sched.max_len - 64 if sched.max_len > 64
                             else sched.max_len // 2 or 1]
    cfg = sched.engine.api.cfg
    tv = cfg.vision_tokens if cfg.family == "vlm" else 0
    max_new = max((s.req.sampling.max_new_tokens
                   for s in sched.slots if s is not None), default=16)
    return serve_signatures(
        prompt_widths, max_new, sched.n_slots, max_len=sched.max_len,
        page_size=sched.page_size, prefill_chunk=sched.prefill_chunk,
        vision_tokens=tv, family=cfg.family)
