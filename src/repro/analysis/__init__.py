"""Static analysis for the serving stack: contract, graph, sharding and
compile-footprint lint (see README "Static analysis & lint").

Three cooperating passes over a built :class:`~repro.serve.engine.
ServeEngine`, rolled into one :class:`~repro.analysis.report.LintReport`:

* :mod:`~repro.analysis.contracts` — declarative pytree schema checks on
  deployed ``ServingWeight`` / ``BitplaneServingWeight`` leaves and paged
  decode caches (rules SW*/BP*/PC*, documented in ``kernels/ops.py``).
* :mod:`~repro.analysis.graph_lint` — jaxpr taint tracking over the
  jitted prefill/decode/chunk programs: dequant materialization, payload
  convert/transpose, decode-state donation.
* :mod:`~repro.analysis.sharding_lint` — replayed spec derivation with
  every ``fit_spec`` drop surfaced, deviceless production meshes
  included.
* :mod:`~repro.analysis.footprint` — static compile-signature census
  mirroring the scheduler's shape decisions.

:func:`lint_engine` is the one-call entry point (the CLI
``python -m repro.launch.lint`` and the ``lint-serving`` CI job wrap
it); individual passes are importable for targeted checks.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from .contracts import (validate_allocation, validate_checkpoint,
                        validate_decode_state, validate_draft_truncation,
                        validate_scheduler, validate_serving_tree)
from .footprint import (CompileSig, chunk_widths, footprint_findings,
                        generate_signatures, scheduler_footprint,
                        serve_signatures)
from .graph_lint import (check_decode_donation, deployed_leaves,
                         fallback_leaf_paths, lint_traced_fn)
from .report import Finding, LintReport
from .sharding_lint import (ShapeOnlyMesh, lint_sharding,
                            production_mesh_shape)

__all__ = [
    "CompileSig", "Finding", "LintReport", "ShapeOnlyMesh",
    "check_decode_donation", "chunk_widths", "deployed_leaves",
    "example_batch", "fallback_leaf_paths", "footprint_findings",
    "generate_signatures", "lint_engine", "lint_sharding",
    "lint_traced_fn", "production_mesh_shape", "scheduler_footprint",
    "serve_signatures", "validate_allocation", "validate_checkpoint",
    "validate_decode_state", "validate_draft_truncation",
    "validate_scheduler", "validate_serving_tree",
]


def example_batch(cfg, batch_size: int, prompt_len: int) -> Dict[str, Any]:
    """Abstract (ShapeDtypeStruct) prompt batch for ``cfg``'s family —
    the lint-side mirror of ``launch.serve._prompts``."""
    sds = jax.ShapeDtypeStruct
    batch = {"tokens": sds((batch_size, prompt_len), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = sds(
            (batch_size, cfg.vision_tokens, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = sds((batch_size, prompt_len, cfg.d_model),
                              jnp.float32)
    return batch


def _roundup64(n: int) -> int:
    return -(-n // 64) * 64


def lint_engine(engine, prompt_len: int = 16, n_slots: int = 4,
                max_new: int = 16, budget: int = 8,
                mesh=None, prompt_widths: Optional[Sequence[int]] = None,
                autotune_budget_bytes: Optional[int] = None) -> LintReport:
    """Run every analysis pass against ``engine``; nothing compiles or
    executes (jaxpr traces + eval_shape only).

    ``mesh`` (a real Mesh or :class:`ShapeOnlyMesh`) additionally runs
    the sharding lint against that topology; ``prompt_widths`` widens the
    compile-footprint census beyond the single ``prompt_len``;
    ``autotune_budget_bytes`` asserts the AT1 budget contract against the
    engine's (presumably autotuned) deployed tree."""
    cfg = engine.api.cfg
    report = LintReport(context={
        "arch": cfg.name, "family": cfg.family, "backend": engine.backend,
        "attn_backend": engine.attn_backend,
        "kv_quant_bits": engine.kv_quant_bits,
        "page_size": engine.page_size,
        "prefill_chunk": engine.prefill_chunk,
        "speculate_planes": engine.speculate_planes,
    })

    # -- contracts ---------------------------------------------------------
    report.extend(validate_serving_tree(engine.params))

    # A bitplane engine that would silently dense-fall-back is an ERROR
    # under preflight (the engine itself only warns at construction):
    # each offending leaf is named so the deploy call can be fixed.
    if engine.backend == "bitplane":
        for p in fallback_leaf_paths(engine.params, engine.backend):
            report.add("error", "contracts", "bitplane-dense-fallback", p,
                       "packed ServingWeight under backend='bitplane' "
                       "executes as an in-graph dense dequant dot — "
                       "deploy with to_serving_params(..., "
                       "layout='bitplane')")

    # -- autotune / speculative contracts (AT1-AT2) ------------------------
    if autotune_budget_bytes is not None:
        report.extend(validate_allocation(engine.params,
                                          autotune_budget_bytes))
    if engine.speculate_planes and engine.draft_params is not None:
        report.extend(validate_draft_truncation(engine.draft_params,
                                                engine.params))

    # -- graph lint --------------------------------------------------------
    batch = example_batch(cfg, 1, prompt_len)
    extra = _roundup64(max_new)
    report.extend(lint_traced_fn(
        lambda p, b: engine.api.prefill(p, b, extra_slots=extra),
        (engine.params, batch), fn_name="prefill", backend=engine.backend,
        attn_backend=engine.attn_backend))

    page_size = 0 if cfg.family == "ssm" else engine.page_size
    max_len = prompt_len + \
        (cfg.vision_tokens if cfg.family == "vlm" else 0) + extra
    try:
        state = jax.eval_shape(
            lambda p, b: engine.api.init_decode_state(
                p, b, n_slots, max_len, page_size=page_size,
                n_pages=engine.n_pages),
            engine.params, batch)
    except Exception as e:
        report.add("error", "graph", "state-shape", "init_decode_state",
                   f"could not derive the decode-state tree "
                   f"({type(e).__name__}: {e})")
        state = None
    if state is not None:
        report.extend(validate_decode_state(state, n_slots=n_slots))
        tokens = jax.ShapeDtypeStruct((n_slots, 1), jnp.int32)
        index = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
        report.extend(lint_traced_fn(
            engine.api.decode_step, (engine.params, tokens, state, index),
            fn_name="decode", backend=engine.backend,
            attn_backend=engine.attn_backend))
        if engine.prefill_chunk > 0 and cfg.family not in ("ssm", "hybrid"):
            cb = {"tokens": jax.ShapeDtypeStruct(
                (1, engine.prefill_chunk), jnp.int32)}
            scalar = jax.ShapeDtypeStruct((), jnp.int32)
            report.extend(lint_traced_fn(
                engine.api.prefill_chunk_at,
                (engine.params, cb, state, scalar, scalar),
                fn_name="chunk", backend=engine.backend,
                attn_backend=engine.attn_backend))
        report.extend(check_decode_donation(engine, tokens, state, index))

    # -- scheduler ledger (PX1-PX3) ----------------------------------------
    # Build a real Scheduler (host-side ledgers only — no device state) and
    # stage a synthetic admission: one slot owning pages with one page
    # registered in the refcounted prefix cache.  validate_scheduler must
    # come back clean, proving the allocator / cache / block-table
    # accounting closes before any workload runs.
    if page_size:
        import numpy as np
        from ..serve.sampling import Request as _Req
        from ..serve.sampling import SamplingParams as _SP
        from ..serve.scheduler import Scheduler, _Slot
        shareable = cfg.family in ("dense", "moe")
        sched = Scheduler(engine, n_slots=n_slots, max_len=max_len,
                          page_size=page_size, n_pages=engine.n_pages,
                          overcommit=2.0, prefix_cache=shareable)
        req = _Req(uid=0,
                   inputs={"tokens": np.zeros((1, page_size + 1), np.int32)},
                   sampling=_SP(max_new_tokens=4, priority=1))
        owned = sched.allocator.alloc(2)
        slot = _Slot(req=req, index=page_size + 1, last_tok=0, generated=[],
                     admitted_tick=0, pages=list(owned), reserve_left=0)
        if sched.prefix_cache is not None:
            sched.prefix_cache.register(b"lint-smoke", slot.pages.pop(0))
            slot.shared_pages.append(owned[0])
            slot.prefix_hashes.append(b"lint-smoke")
        sched.slots[0] = slot
        sched.tables[0, :slot.n_blocks] = slot.block_pages
        report.extend(validate_scheduler(sched))
        report.add("info", "contracts", "PX-smoke", "scheduler",
                   f"ledger smoke ran: {len(owned)} pages, prefix cache "
                   f"{'on' if sched.prefix_cache is not None else 'off'}, "
                   f"overcommit {sched.overcommit}")

    # -- compile footprint -------------------------------------------------
    sigs = serve_signatures(
        list(prompt_widths or [prompt_len]), max_new, n_slots,
        max_len=max_len, page_size=page_size,
        prefill_chunk=engine.prefill_chunk,
        vision_tokens=cfg.vision_tokens if cfg.family == "vlm" else 0,
        family=cfg.family)
    report.extend(footprint_findings(sigs, budget=budget))

    # -- sharding ----------------------------------------------------------
    mesh = mesh if mesh is not None else engine.mesh
    if mesh is not None:
        report.extend(lint_sharding(engine.params, mesh, batch=batch,
                                    state=state, n_slots=n_slots))
    return report
