"""Sharding lint: validate every param/batch/cache PartitionSpec against
a mesh and report per-leaf what the rule layer silently weakened.

The rule layer (``dist/sharding.py``) is written for the production mesh
and *degrades* everywhere else: :func:`~repro.dist.sharding.fit_spec`
drops axes that are absent, already used, or do not divide the dim.
That is the right runtime behavior and the wrong silent behavior — a
weight that was supposed to be 16-way model-parallel serving replicated
is a 16x memory/bandwidth regression the parity tests cannot see.  This
pass replays the full spec derivation under
:func:`~repro.dist.sharding.collect_spec_events` and turns every drop
into a path-qualified finding:

* ``axis-padded`` (info) — the mesh axis does not divide the dim but
  padded sharding keeps it: the placement boundary zero-pads and the
  consumer masks (the healthy resolution of what used to be an
  ``axis-indivisible`` drop).
* ``axis-indivisible`` (warning) — the mesh axis exists but does not
  divide the dim AND padding was disabled for that call site (in-graph
  ``with_sharding_constraint``, batch placement): the dim serves
  replicated.
* ``axis-absent`` / ``axis-used`` (info) — expected degradation when
  linting a smaller mesh than the rules target.
* ``mesh-axis-unused`` (warning) — a >1-sized mesh axis no parameter
  leaf uses at all: devices along it hold fully replicated weights.

Production meshes are linted *devicelessly*: the rule layer only ever
consults ``mesh.shape``, so :class:`ShapeOnlyMesh` stands in for a real
``jax.sharding.Mesh`` of any size on a 1-device dev box.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax

from .report import Finding


class ShapeOnlyMesh:
    """Deviceless mesh stand-in: just the axis-name -> size mapping.

    Sufficient for every pure rule-layer entry point (``fit_spec``,
    ``param_pspecs``, ``batch_pspecs``, ``cache_pspecs``) — anything that
    would ``device_put`` needs a real mesh."""

    def __init__(self, shape: Dict[str, int]):
        self.shape = dict(shape)

    def __repr__(self):
        return f"ShapeOnlyMesh({self.shape})"


def production_mesh_shape(multi_pod: bool = False) -> Dict[str, int]:
    """Axis sizes of ``launch.mesh.make_production_mesh`` without needing
    its 256/512 devices."""
    return {"pod": 2, "data": 16, "model": 16} if multi_pod \
        else {"data": 16, "model": 16}


_DROP_RULES = {"indivisible": ("warning", "axis-indivisible"),
               "absent": ("info", "axis-absent"),
               "used": ("info", "axis-used")}


def _spec_axes(specs) -> set:
    """Every mesh axis name used anywhere in a tree of PartitionSpecs."""
    from jax.sharding import PartitionSpec as P
    axes = set()
    for s in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)):
        if not isinstance(s, P):
            continue
        for entry in s:
            if entry is None:
                continue
            axes.update(entry if isinstance(entry, tuple) else (entry,))
    return axes


def lint_sharding(params: Any, mesh, batch: Any = None, state: Any = None,
                  n_slots: int = 8) -> List[Finding]:
    """Replay spec derivation for ``params`` (+ optional ``batch`` /
    decode ``state``) under ``mesh`` and lint the drops.

    ``mesh`` may be a real ``jax.sharding.Mesh`` or a
    :class:`ShapeOnlyMesh`."""
    from ..dist.sharding import (batch_pspecs, cache_pspecs,
                                 collect_spec_events, param_pspecs,
                                 use_mesh)
    findings: List[Finding] = []
    with use_mesh(mesh), collect_spec_events() as events:
        specs = param_pspecs(params)
        if batch is not None:
            batch_pspecs(batch)
        if state is not None:
            cache_pspecs(state, n_slots)
    from ..dist.sharding import SpecPad
    for d in events:
        if isinstance(d, SpecPad):
            findings.append(Finding(severity="info", pass_name="sharding",
                                    rule="axis-padded", path=d.label,
                                    message=d.message()))
            continue
        severity, rule = _DROP_RULES.get(d.reason, ("warning", "axis-drop"))
        findings.append(Finding(severity=severity, pass_name="sharding",
                                rule=rule, path=d.label,
                                message=d.message()))
    used = _spec_axes(specs)
    for axis, size in mesh.shape.items():
        if size > 1 and axis not in used:
            findings.append(Finding(
                severity="warning", pass_name="sharding",
                rule="mesh-axis-unused", path=f"mesh.{axis}",
                message=f"mesh axis {axis!r} (size {size}) is used by no "
                        f"parameter spec: weights replicate {size}x along "
                        f"it"))
    if not findings:
        findings.append(Finding(
            severity="info", pass_name="sharding", rule="clean",
            path="<tree>",
            message=f"all requested specs fit mesh {dict(mesh.shape)} "
                    f"with no drops"))
    return findings
