"""Declarative contract validation for deployed serving pytrees.

The serving wire formats (:class:`repro.serve.deploy.ServingWeight`,
:class:`repro.serve.deploy.BitplaneServingWeight`) and the paged decode
cache carry invariants the type system cannot express — block geometry
derived from the per-WB scale grid, nibble/byte padding of odd
block-padded K, plane-occupancy masks, block-table/page-pool agreement.
This module checks them *statically* (shapes/dtypes always; cheap value
invariants when leaves are concrete) and reports one path-qualified
:class:`~repro.analysis.report.Finding` per violation instead of letting
a corrupted tree crash a kernel mid-serve.

Rules (cross-referenced by the contract appendix in ``kernels/ops.py``):

* ``SW1``  geometry: ``scale`` is (..., GR, GC); Kp = GR*wbr, Np = GC*wbc.
* ``SW2``  true shape: (K, N) = ``shape[-2:]`` with K <= Kp < K + wbr and
  N <= Np < N + wbc (the block grid is the minimal cover).
* ``SW3``  stack dims LEAD: ``w_int``/``scale`` share ``shape[:-2]``.
* ``SW4``  payload: bits=8 -> int8 (..., Kp, Np); bits=4 -> uint8
  (..., ceil(Kp/2), Np) nibble pairs; an odd Kp's high pad nibble is 0.
* ``BP1``  plane tensors: ``planes`` (..., bits, Kp8//8, Np) uint8 and
  ``sign`` (..., Kp8//8, Np) uint8 with Kp8 = ceil(Kp/8)*8; byte-pad rows
  beyond Kp hold zeros.
* ``BP2``  mask LUT: (..., bits, GR, GC) binary, prefix-monotone along
  the bit axis (occupancy = min(bw, bits) live LOW planes), f32.
* ``BP3``  scale LUT: (..., GR, GC) f32, finite.
* ``PC1``  paged cache: pool leaves agree on (stack, n_pages, page_size)
  leading dims; ``table`` is integer (stack, n_slots, nb).
* ``PC2``  block tables: every entry in [0, n_pages); page 0 is the
  reserved trash page; a non-zero page owned by two slots is flagged
  unless a refcount ledger (the scheduler's ``PrefixCache``) accounts
  for the sharing.
* ``PC3``  quantized pools carry their per-token scale leaves.
* ``PA1``  fused-kernel pool layout: ``k``/``v`` agree on dtype and full
  shape; scale leaves match the payload's (stack, n_pages, page, KV)
  prefix; an int4 (uint8) pool's packed head dim unpacks to an even
  head dim (nibble pairs along dh).
* ``PA2``  pool capacity: >= 2 pages (the reserved trash page 0 plus at
  least one allocatable page) and >= 1 table block per slot.
* ``PA3``  concrete block tables: each slot's live (non-zero) pages form
  a contiguous prefix of its row — the kernel walks blocks 0..nb-1 and
  relies on the fill level masking only the trash-page *tail*.
* ``PX1``  refcount consistency (:func:`validate_scheduler`): every
  prefix-cache refcount equals the number of live slots aliasing that
  page, every slot-shared page is registered, and the allocator's
  ``in_use`` equals the distinct pages owned by live slots + the cache
  (so parked snapshots hold NO pool pages and the pool drains to zero).
* ``PX2``  no write to a shared page: each slot's write frontier
  (``index``) sits at or past the end of its shared-prefix region —
  shared pages are read-only by construction (the hashed region stops
  at least one token before the first writable position), and
  copy-on-write is the enforcement backstop.
* ``PX3``  parked-slot table hygiene: a free or parked slot's block
  table row is all trash-page zeros, and a live slot's row mirrors its
  book-kept (shared + owned) pages exactly — a parked request's pages
  live only in its host snapshot, never in the device tables.
* ``AT1``  an autotuned assignment respects its byte budget exactly per
  ``weight_stream_bytes`` (:func:`validate_allocation`).
* ``AT2``  a speculative draft tree is a pure top-k mask-truncation view
  of the deployed tree: shared payloads, each block keeping the
  contiguous top run of its min(k, occupancy) highest live planes
  (:func:`validate_draft_truncation`).
* ``CK1``  checkpoint META is well-formed (:func:`validate_checkpoint`):
  known format, manifest entries carry (key, shape, dtype, spec), every
  spec axis exists in ``mesh_axes`` and its axis group divides the dim
  (chunking must tile each leaf exactly), sanitized npz keys are unique.
* ``CK2``  shard set is complete: every ``shard_*-of-*.npz`` file META
  promises exists and every manifest leaf's owning shards hold a chunk
  of exactly the expected shape and dtype — a torn or elastically
  mis-assembled save is caught before restore.
* ``CK3``  no orphans: shard files hold no arrays absent from the
  manifest, and the checkpoint directory has no stale ``.tmp``/``.old``
  commit debris (warning — a crashed save's leftovers).
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import numpy as np

from .report import Finding

_FLOATS = ("float32",)


def _concrete(x) -> Optional[np.ndarray]:
    """Host array for value-level checks; None for abstract/traced leaves."""
    if isinstance(x, np.ndarray):
        return x
    if isinstance(x, jax.Array):
        try:
            return np.asarray(x)
        except Exception:
            return None
    return None


def _shape(x) -> tuple:
    return tuple(getattr(x, "shape", ()))


def _dtype(x) -> str:
    return str(getattr(x, "dtype", "?"))


class _Ctx:
    """Per-leaf finding accumulator with the leaf path pre-bound."""

    def __init__(self, findings: List[Finding], path: str):
        self.findings = findings
        self.path = path

    def err(self, rule: str, msg: str, sub: str = "") -> None:
        self.findings.append(Finding(
            severity="error", pass_name="contracts", rule=rule,
            path=self.path + sub, message=msg))

    def warn(self, rule: str, msg: str, sub: str = "") -> None:
        self.findings.append(Finding(
            severity="warning", pass_name="contracts", rule=rule,
            path=self.path + sub, message=msg))


def _grid_geometry(c: _Ctx, scale, spec, shape) -> Optional[tuple]:
    """Shared SW1/SW2/BP3 geometry: returns (lead, K, N, Kp, Np) or None."""
    sshape = _shape(scale)
    if len(sshape) < 2:
        c.err("SW1", f"per-WB scale must be (..., GR, GC), got {sshape}",
              ".scale")
        return None
    wbr, wbc = spec.wb_rows, spec.wb_cols
    gr, gc = sshape[-2], sshape[-1]
    kp, np_ = gr * wbr, gc * wbc
    if not (isinstance(shape, tuple) and len(shape) >= 2):
        c.err("SW2", f"true shape must be a (..., K, N) tuple, got {shape!r}",
              ".shape")
        return None
    k, n = shape[-2], shape[-1]
    lead = tuple(shape[:-2])
    if not (0 < k <= kp and kp - k < wbr):
        c.err("SW2", f"scale grid GR={gr} (Kp={kp}) is not the minimal "
                     f"{wbr}-row cover of K={k}", ".scale")
    if not (0 < n <= np_ and np_ - n < wbc):
        c.err("SW2", f"scale grid GC={gc} (Np={np_}) is not the minimal "
                     f"{wbc}-col cover of N={n}", ".scale")
    if sshape[:-2] != lead:
        c.err("SW3", f"scale stack dims {sshape[:-2]} != leaf stack dims "
                     f"{lead} (layer-stack dims must LEAD)", ".scale")
    if _dtype(scale) not in _FLOATS:
        c.err("BP3", f"scale LUT must be float32, got {_dtype(scale)}",
              ".scale")
    sval = _concrete(scale)
    if sval is not None and not np.isfinite(sval).all():
        c.err("BP3", "scale LUT has non-finite entries", ".scale")
    return lead, k, n, kp, np_


def _check_serving_weight(c: _Ctx, sw) -> None:
    geo = _grid_geometry(c, sw.scale, sw.spec, sw.shape)
    if geo is None:
        return
    lead, k, n, kp, np_ = geo
    wshape = _shape(sw.w_int)
    if sw.bits == 8:
        want = lead + (kp, np_)
        if wshape != want:
            c.err("SW4", f"int8 payload shape {wshape} != {want}", ".w_int")
        if _dtype(sw.w_int) != "int8":
            c.err("SW4", f"bits=8 payload must be int8, got "
                         f"{_dtype(sw.w_int)}", ".w_int")
    elif sw.bits == 4:
        want = lead + (-(-kp // 2), np_)
        if wshape != want:
            c.err("SW4", f"int4 nibble payload shape {wshape} != {want} "
                         f"(pairs packed along K, odd Kp pads one zero row)",
                  ".w_int")
        if _dtype(sw.w_int) != "uint8":
            c.err("SW4", f"bits=4 payload must be uint8 nibble pairs, got "
                         f"{_dtype(sw.w_int)}", ".w_int")
        wval = _concrete(sw.w_int)
        if wval is not None and kp % 2 and wshape == want:
            pad = wval[..., -1, :] >> 4
            if np.any(pad):
                c.err("SW4", f"odd block-padded K={kp}: high pad nibble of "
                             f"the last byte row must be 0, found "
                             f"{int((pad != 0).sum())} non-zero entries",
                      ".w_int")
    else:
        c.err("SW4", f"bits must be 4 or 8, got {sw.bits}", ".bits")
        return
    if len(wshape) >= 2 and wshape[:-2] != lead:
        c.err("SW3", f"payload stack dims {wshape[:-2]} != leaf stack dims "
                     f"{lead} (layer-stack dims must LEAD)", ".w_int")


def _check_bitplane_weight(c: _Ctx, sw) -> None:
    geo = _grid_geometry(c, sw.scale, sw.spec, sw.shape)
    if geo is None:
        return
    lead, k, n, kp, np_ = geo
    kp8 = -(-kp // 8) * 8
    bits = sw.bits
    pshape, gshape, mshape = _shape(sw.planes), _shape(sw.sign), \
        _shape(sw.mask)
    want_p = lead + (bits, kp8 // 8, np_)
    if pshape != want_p:
        c.err("BP1", f"packed planes shape {pshape} != {want_p} "
                     f"(bits, byte-padded K rows, Np; stack dims lead)",
              ".planes")
    want_s = lead + (kp8 // 8, np_)
    if gshape != want_s:
        c.err("BP1", f"packed sign plane shape {gshape} != {want_s} "
                     f"(truncated/misaligned sign plane)", ".sign")
    for name, leaf in (("planes", sw.planes), ("sign", sw.sign)):
        if _dtype(leaf) != "uint8":
            c.err("BP1", f"{name} must be uint8 bit-packed, got "
                         f"{_dtype(leaf)}", f".{name}")
    want_m = lead + (bits, gr_gc[0], gr_gc[1]) \
        if (gr_gc := _shape(sw.scale)[-2:]) else None
    if mshape != want_m:
        c.err("BP2", f"mask LUT shape {mshape} != {want_m} "
                     f"((bits, GR, GC) with stack dims leading)", ".mask")
    if _dtype(sw.mask) not in _FLOATS:
        c.err("BP2", f"mask LUT must be float32 in {{0, 1}}, got "
                     f"{_dtype(sw.mask)}", ".mask")
    mval = _concrete(sw.mask)
    if mval is not None and mshape == want_m:
        binary = np.isin(mval, (0.0, 1.0))
        if not binary.all():
            c.err("BP2", f"mask LUT must be binary; "
                         f"{int((~binary).sum())} entries outside {{0, 1}} "
                         f"(max {float(np.max(mval))})", ".mask")
        else:
            occ = mval.sum(axis=-3)
            if occ.size and occ.max() > bits:
                c.err("BP2", f"plane occupancy {int(occ.max())} exceeds the "
                             f"container bits={bits}", ".mask")
            # live planes must be the LOW planes: occupancy is a prefix
            prefix = np.cumprod(mval, axis=-3)
            if not np.array_equal(prefix, mval):
                c.err("BP2", "mask is not prefix-monotone along the bit "
                             "axis: a live plane b requires plane b-1 live "
                             "(occupancy = min(bw, bits) LOW planes)",
                      ".mask")
    if kp8 > kp and kp % 8:
        # byte-pad rows live in the last byte row: bit positions kp%8..7
        padmask = np.uint8(0xFF & ~((1 << (kp % 8)) - 1))
        for name, leaf, want in (("planes", sw.planes, want_p),
                                 ("sign", sw.sign, want_s)):
            val = _concrete(leaf)
            if val is not None and _shape(leaf) == want \
                    and np.any(val[..., kp // 8, :] & padmask):
                c.err("BP1", f"byte-pad rows [{kp}, {kp8}) of {name} "
                             f"must be zero", f".{name}")


def _deployed_types():
    from ..serve.deploy import BitplaneServingWeight, ServingWeight
    return ServingWeight, BitplaneServingWeight


def iter_deployed_leaves(params: Any):
    """Yield (keystr path, leaf) for every deployed serving leaf."""
    sw_t, bp_t = _deployed_types()
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, (sw_t, bp_t)))
    for path, leaf in flat:
        if isinstance(leaf, (sw_t, bp_t)):
            yield jax.tree_util.keystr(path), leaf


def validate_serving_tree(params: Any) -> List[Finding]:
    """Contract-check every deployed leaf of ``params``.

    Never raises on a malformed tree: a leaf whose corruption breaks the
    validator itself still yields one path-qualified error finding."""
    sw_t, bp_t = _deployed_types()
    findings: List[Finding] = []
    n_checked = 0
    for path, leaf in iter_deployed_leaves(params):
        c = _Ctx(findings, path)
        n_checked += 1
        try:
            if isinstance(leaf, bp_t):
                _check_bitplane_weight(c, leaf)
            else:
                _check_serving_weight(c, leaf)
        except Exception as e:                      # corrupted beyond checks
            c.err("SW0", f"validator could not interpret this leaf "
                         f"({type(e).__name__}: {e})")
    if n_checked == 0:
        findings.append(Finding(
            severity="info", pass_name="contracts", rule="SW0",
            path="<tree>", message="no deployed serving leaves to check"))
    return findings


# ---------------------------------------------------------------------------
# decode-state / paged-cache validation
# ---------------------------------------------------------------------------

def _walk_paged(cache, path, findings: List[Finding],
                n_slots: Optional[int],
                refcounts: Optional[dict] = None) -> None:
    if not isinstance(cache, dict):
        return
    if "table" in cache:
        c = _Ctx(findings, path)
        table, pages = cache["table"], cache.get("pages")
        if not np.issubdtype(np.dtype(_dtype(table)), np.integer):
            c.err("PC1", f"block table must be integer, got {_dtype(table)}",
                  "['table']")
        tshape = _shape(table)
        if len(tshape) != 3:
            c.err("PC1", f"block table must be (stack, n_slots, nb), got "
                         f"{tshape}", "['table']")
            return
        if n_slots is not None and tshape[1] != n_slots:
            c.err("PC1", f"block table holds {tshape[1]} slots, scheduler "
                         f"has {n_slots}", "['table']")
        if not isinstance(pages, dict) or not pages:
            c.err("PC1", "paged KV node has a table but no page pool",
                  "['pages']")
            return
        heads = {name: _shape(leaf)[:3] for name, leaf in pages.items()}
        first = next(iter(heads.values()))
        for name, h in heads.items():
            if len(h) < 3:
                c.err("PC1", f"pool leaf must be (stack, n_pages, "
                             f"page_size, ...), got {_shape(pages[name])}",
                      f"['pages']['{name}']")
                return
            if h != first:
                c.err("PC1", f"pool leaves disagree on (stack, n_pages, "
                             f"page_size): {heads}",
                      f"['pages']['{name}']")
        n_pages = first[1]
        if tshape[0] != first[0]:
            c.err("PC1", f"table stack dim {tshape[0]} != pool stack dim "
                         f"{first[0]}", "['table']")
        quantized = any(_dtype(v) in ("int8", "uint8")
                        for k, v in pages.items() if k in ("k", "v"))
        if quantized and not any(k.endswith("_scale") for k in pages):
            c.err("PC3", "quantized page pool is missing its per-token "
                         "scale leaves", "['pages']")
        # -- PA*: fused-kernel page-table invariants ------------------
        if "k" in pages and "v" in pages:
            kl, vl = pages["k"], pages["v"]
            if _dtype(kl) != _dtype(vl) or _shape(kl) != _shape(vl):
                c.err("PA1", f"k/v pool leaves disagree: "
                             f"{_dtype(kl)}{_shape(kl)} vs "
                             f"{_dtype(vl)}{_shape(vl)} (the fused kernel "
                             f"dequantizes both with one code path)",
                      "['pages']")
            if _dtype(kl) not in ("int8", "uint8", "float32"):
                c.err("PA1", f"pool payload dtype {_dtype(kl)} is not a "
                             f"storage format the fused kernel dequantizes "
                             f"(int8, uint8 nibble pairs, or float32)",
                      "['pages']['k']")
            for name in ("k_scale", "v_scale"):
                if name not in pages:
                    continue
                want = _shape(pages[name[0]])[:4]
                if _shape(pages[name]) != want:
                    c.err("PA1", f"scale leaf shape {_shape(pages[name])} "
                                 f"!= payload (stack, n_pages, page, KV) "
                                 f"prefix {want}",
                          f"['pages']['{name}']")
                if _dtype(pages[name]) not in _FLOATS:
                    c.err("PA1", f"per-token scale must be float32, got "
                                 f"{_dtype(pages[name])}",
                          f"['pages']['{name}']")
        if n_pages < 2:
            c.err("PA2", f"page pool holds {n_pages} page(s); needs the "
                         f"reserved trash page 0 plus at least one "
                         f"allocatable page", "['pages']")
        if tshape[2] < 1:
            c.err("PA2", f"block table has {tshape[2]} blocks per slot; "
                         f"the fused kernel's grid needs nb >= 1",
                  "['table']")
        tval = _concrete(table)
        if tval is not None:
            bad = (tval < 0) | (tval >= n_pages)
            if bad.any():
                ids = sorted(set(int(v) for v in tval[bad]))[:8]
                c.err("PC2", f"{int(bad.sum())} block-table entries "
                             f"reference pages outside the pool "
                             f"[0, {n_pages}): orphaned ids {ids}",
                      "['table']")
            live = tval[0][tval[0] != 0]          # stack dim 0 is broadcast
            uniq, counts = np.unique(live, return_counts=True)
            shared = uniq[counts > 1]
            ledger = refcounts or {}
            unbooked = [int(p) for p in shared if int(p) not in ledger]
            if unbooked:
                c.warn("PC2", f"non-zero pages owned by multiple slots "
                              f"with no refcount ledger entry (enable "
                              f"prefix_cache for safe sharing): "
                              f"{unbooked[:8]}", "['table']")
            # PA3: live pages must be a contiguous per-row prefix — the
            # fused kernel walks blocks 0..nb-1 and only the *tail* may
            # point at the trash page (masked by the fill level)
            occ = tval[0] != 0                    # (n_slots, nb)
            holes = (~occ[:, :-1]) & occ[:, 1:]
            if holes.any():
                rows = sorted(set(int(r) for r in np.where(holes)[0]))[:8]
                c.err("PA3", f"slot rows {rows} have live pages after a "
                             f"trash-page hole; live blocks must be a "
                             f"contiguous prefix of the row", "['table']")
        return
    for key, sub in cache.items():
        _walk_paged(sub, f"{path}['{key}']", findings, n_slots, refcounts)


def validate_decode_state(state: Any, n_slots: Optional[int] = None,
                          refcounts: Optional[dict] = None) -> List[Finding]:
    """Contract-check a decode state's paged KV sub-trees (PC1-PC3).

    Contiguous states have nothing paged to check and validate trivially;
    corrupted paged trees produce path-qualified findings, not crashes.
    ``refcounts`` (page id -> count, from the scheduler's prefix cache)
    marks pages whose multi-slot ownership is deliberate — shared pages
    *outside* the ledger still warn under PC2."""
    findings: List[Finding] = []
    cache = state.get("cache", state) if isinstance(state, dict) else state
    try:
        _walk_paged(cache, "state['cache']", findings, n_slots, refcounts)
    except Exception as e:
        findings.append(Finding(
            severity="error", pass_name="contracts", rule="PC0",
            path="state['cache']",
            message=f"validator could not walk this cache tree "
                    f"({type(e).__name__}: {e})"))
    return findings


def validate_scheduler(sched) -> List[Finding]:
    """PX1-PX3: live-scheduler ledger checks (duck-typed on
    :class:`repro.serve.scheduler.Scheduler`).

    These validate the *host-side* book-keeping the device tables are
    written from — refcount consistency between the prefix cache and the
    slots aliasing its pages (PX1), the shared-region/write-frontier
    separation that makes shared pages read-only (PX2), and block-table
    hygiene for free/parked rows (PX3).  Non-paged schedulers validate
    trivially."""
    findings: List[Finding] = []
    c = _Ctx(findings, "scheduler")
    if not getattr(sched, "paged", False) or sched.tables is None:
        return findings
    ps = sched.page_size
    live = {i: s for i, s in enumerate(sched.slots) if s is not None}
    # -- PX1: refcounts mirror live aliases; pool accounting closes -------
    owned: dict = {}
    for i, s in live.items():
        for p in s.pages:
            owned[p] = owned.get(p, 0) + 1
    held: dict = {}
    for i, s in live.items():
        for p in s.shared_pages:
            held[p] = held.get(p, 0) + 1
    if sched.prefix_cache is not None:
        refs = sched.prefix_cache.refcounts
        for p, n in refs.items():
            if held.get(p, 0) != n:
                c.err("PX1", f"page {p} has refcount {n} but "
                             f"{held.get(p, 0)} live slot(s) alias it")
        for p in held:
            if p not in refs:
                c.err("PX1", f"slot-shared page {p} is not registered in "
                             f"the prefix cache")
        for p in refs:
            owned[p] = owned.get(p, 0) + 1
    else:
        for p, n in held.items():
            owned[p] = owned.get(p, 0) + n
    multi = sorted(p for p, n in owned.items() if n > 1)
    if multi:
        c.err("PX1", f"pages owned more than once (slot-private lists / "
                     f"cache registry overlap): {multi[:8]}")
    if sched.allocator.in_use != len(owned):
        c.err("PX1", f"allocator reports {sched.allocator.in_use} pages in "
                     f"use but live slots + prefix cache own {len(owned)} "
                     f"(parked snapshots must hold no pool pages)")
    # -- PX2: shared prefix strictly behind the write frontier ------------
    for i, s in live.items():
        if s.n_shared and s.index < s.n_shared * ps:
            c.err("PX2", f"slot {i} write frontier {s.index} falls inside "
                         f"its shared-prefix region [0, {s.n_shared * ps}) "
                         f"— a decode/prefill write would corrupt a page "
                         f"other requests alias")
    # -- PX3: device tables mirror the ledger; parked rows are zeroed -----
    for i in range(sched.n_slots):
        row = np.asarray(sched.tables[i])
        s = sched.slots[i]
        if s is None:
            stale = sorted(set(int(p) for p in row[row != 0]))
            if stale:
                c.err("PX3", f"free/parked slot row {i} still references "
                             f"pages {stale[:8]}; swapped-out state lives "
                             f"in the host snapshot only")
        else:
            bp = [int(p) for p in s.block_pages]
            if [int(p) for p in row[:len(bp)]] != bp or row[len(bp):].any():
                c.err("PX3", f"slot {i} table row {row.tolist()} does not "
                             f"mirror its book-kept pages {bp}")
    return findings


# ---------------------------------------------------------------------------
# checkpoint shard-manifest validation (CK1-CK3)
# ---------------------------------------------------------------------------

def validate_checkpoint(path: str) -> List[Finding]:
    """CK1-CK3: validate a sharded checkpoint directory on disk.

    Static (META vs. file set vs. npz headers) — no leaf is assembled,
    so it is cheap even for multi-GB checkpoints.  Legacy (format 1)
    monolithic checkpoints validate trivially."""
    import json
    import math
    import os
    import re as _re

    findings: List[Finding] = []
    c = _Ctx(findings, path)
    meta_path = os.path.join(path, "META")
    if not os.path.exists(meta_path):
        c.err("CK1", "no META file: not a checkpoint directory")
        return findings
    try:
        with open(meta_path) as f:
            meta = json.load(f)
    except Exception as e:
        c.err("CK1", f"META is not valid JSON ({type(e).__name__}: {e})")
        return findings
    fmt = meta.get("format", 1)
    if fmt == 1:
        if not os.path.exists(os.path.join(path, "arrays.npz")):
            c.err("CK2", "legacy checkpoint is missing arrays.npz")
        return findings
    if fmt != 2:
        c.err("CK1", f"unknown checkpoint format {fmt!r}")
        return findings
    manifest = meta.get("manifest")
    mesh_axes = meta.get("mesh_axes", {})
    axes = meta.get("shard_axes", [])
    hosts = meta.get("hosts", [])
    n = meta.get("n_shards", 0)
    if not isinstance(manifest, dict) or not isinstance(hosts, list) \
            or len(hosts) != n:
        c.err("CK1", f"META manifest/hosts malformed "
                     f"(n_shards={n}, hosts={len(hosts) if isinstance(hosts, list) else '?'})")
        return findings
    from ..ckpt.checkpoint import _chunk_slices
    sanitized: dict = {}
    for key, ent in manifest.items():
        sub = f"[{key!r}]"
        if not all(f in ent for f in ("key", "shape", "dtype", "spec")):
            c.err("CK1", f"manifest entry lacks key/shape/dtype/spec fields",
                  sub)
            continue
        sk = ent["key"]
        if sk in sanitized:
            c.err("CK1", f"sanitized npz key {sk!r} collides with "
                         f"{sanitized[sk]!r}", sub)
        sanitized[sk] = key
        shape, spec = ent["shape"], ent["spec"]
        if len(spec) != len(shape):
            c.err("CK1", f"spec has {len(spec)} entries for a rank-"
                         f"{len(shape)} leaf", sub)
            continue
        for dim, entry in zip(shape, spec):
            if not entry:
                continue
            group = 1
            for a in entry:
                if a not in mesh_axes:
                    c.err("CK1", f"spec axis {a!r} not in the saving "
                                 f"mesh axes {sorted(mesh_axes)}", sub)
                    group = 0
                    break
                group *= mesh_axes[a]
            if group and dim % group:
                c.err("CK1", f"dim {dim} is not divisible by its axis "
                             f"group {entry} (size {group}): chunks "
                             f"cannot tile the leaf", sub)
    # -- CK2: every promised shard file exists and holds the right chunks
    shard_files = {h: f"shard_{h:05d}-of-{n:05d}.npz" for h in range(n)}
    headers: dict = {}
    for h, name in shard_files.items():
        fp = os.path.join(path, name)
        if not os.path.exists(fp):
            c.err("CK2", f"missing shard file {name} "
                         f"(host {hosts[h] if h < len(hosts) else '?'})")
            continue
        try:
            z = np.load(fp)
            headers[h] = {k: (z[k].shape, str(z[k].dtype)) for k in z.files}
            z.close()
        except Exception as e:
            c.err("CK2", f"unreadable shard file {name} "
                         f"({type(e).__name__}: {e})")
    coord_maps = [dict(zip(axes, co)) for co in hosts]
    for key, ent in manifest.items():
        if not all(f in ent for f in ("key", "shape", "dtype", "spec")):
            continue
        shape = tuple(ent["shape"])
        for h, coords in enumerate(coord_maps):
            if h not in headers:
                continue
            sl = _chunk_slices(shape, ent["spec"], mesh_axes, coords)
            if sl is None:
                if ent["key"] in headers[h]:
                    c.err("CK3", f"shard {h} holds a chunk of "
                                 f"[{key!r}] it does not own")
                continue
            got = headers[h].get(ent["key"])
            want = tuple(len(range(*s.indices(d)))
                         for s, d in zip(sl, shape))
            if got is None:
                c.err("CK2", f"shard {h} is missing its chunk of "
                             f"[{key!r}]")
            elif got != (want, ent["dtype"]):
                c.err("CK2", f"shard {h} chunk of [{key!r}] is "
                             f"{got[1]}{got[0]}, expected "
                             f"{ent['dtype']}{want}")
    # -- CK3: orphan arrays + commit debris
    expected = {ent["key"] for ent in manifest.values() if "key" in ent}
    for h, hdr in headers.items():
        orphans = sorted(set(hdr) - expected)
        if orphans:
            c.err("CK3", f"shard {h} holds {len(orphans)} arrays absent "
                         f"from the manifest: {orphans[:4]}")
    parent = os.path.dirname(os.path.abspath(path))
    base = os.path.basename(os.path.abspath(path))
    for name in os.listdir(parent):
        if _re.fullmatch(_re.escape(base) + r"\.(tmp|old)\.[0-9a-f]{8}",
                         name):
            c.warn("CK3", f"stale commit debris {name!r} next to the "
                          f"checkpoint (crashed save; gc will sweep it)")
    if not findings:
        findings.append(Finding(
            severity="info", pass_name="contracts", rule="CK0",
            path=path, message=f"checkpoint valid: {len(manifest)} leaves "
                               f"across {n} shard(s), mesh {mesh_axes}"))
    return findings


# ---------------------------------------------------------------------------
# autotune / speculative-draft validation (AT1-AT2)
# ---------------------------------------------------------------------------

def validate_allocation(params: Any, budget_bytes: int) -> List[Finding]:
    """AT1: an autotuned tree respects its byte budget exactly.

    The check re-derives the total through ``weight_stream_bytes`` — the
    same per-block occupancy accounting the allocator optimized against —
    so allocator and contract cannot drift apart silently."""
    from ..serve.deploy import weight_stream_bytes
    findings: List[Finding] = []
    total = weight_stream_bytes(params)
    if total > budget_bytes:
        findings.append(Finding(
            severity="error", pass_name="contracts", rule="AT1",
            path="<tree>",
            message=f"allocation streams {total} B per step, over the "
                    f"{budget_bytes} B budget"))
    return findings


def validate_draft_truncation(draft: Any, deployed: Any) -> List[Finding]:
    """AT2: a draft tree is a pure top-k mask-truncation view.

    For every bitplane leaf pair: payload tensors (planes/sign/scale)
    must be shared with the deployed tree, and each block's draft mask
    must keep a contiguous run of the HIGHEST deployed live planes —
    i.e. the draft reads a strict subset of the bytes the verify pass
    streams, with a single truncation depth k across the tree."""
    _, bp_t = _deployed_types()
    findings: List[Finding] = []
    dep = {p: leaf for p, leaf in iter_deployed_leaves(deployed)
           if isinstance(leaf, bp_t)}
    drf = {p: leaf for p, leaf in iter_deployed_leaves(draft)
           if isinstance(leaf, bp_t)}
    if set(dep) != set(drf):
        findings.append(Finding(
            severity="error", pass_name="contracts", rule="AT2",
            path="<tree>",
            message=f"draft/deployed bitplane leaves differ: "
                    f"{sorted(set(dep) ^ set(drf))[:4]}"))
        return findings
    for p in sorted(dep):
        c = _Ctx(findings, p)
        d, f = dep[p], drf[p]
        for name in ("planes", "sign", "scale"):
            if getattr(d, name) is not getattr(f, name):
                c.warn("AT2", f".{name} is not shared with the deployed "
                              f"tree (draft should be a zero-copy view)")
        dm, fm = _concrete(d.mask), _concrete(f.mask)
        if dm is None or fm is None:
            continue
        if fm.shape != dm.shape:
            c.err("AT2", f".mask shape {fm.shape} != deployed {dm.shape}")
            continue
        if np.any((fm > 0) & (dm == 0)):
            c.err("AT2", ".mask lights planes dead in the deployed tree "
                         "(draft must be a subset view)")
            continue
        occ = dm.sum(axis=-3)                          # (..., GR, GC)
        k_blk = fm.sum(axis=-3)
        bits = dm.shape[-3]
        idx = np.arange(bits).reshape((bits, 1, 1))
        want = ((idx >= occ[..., None, :, :] - k_blk[..., None, :, :])
                & (idx < occ[..., None, :, :])).astype(fm.dtype)
        if not np.array_equal(fm, want):
            c.err("AT2", ".mask is not a contiguous top run of the "
                         "deployed live planes")
            continue
        # one truncation depth k per leaf: every block keeps min(occ, k)
        if k_blk.size and float(k_blk.max()) > 0:
            k = float(k_blk.max())
            if not np.array_equal(k_blk, np.minimum(occ, k)):
                c.err("AT2", "inconsistent truncation depth across blocks "
                             "(mask is not a single top-k view)")
    return findings
