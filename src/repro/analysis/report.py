"""Lint findings and the report they roll up into.

Every analysis pass (graph lint, contract validator, sharding lint)
emits :class:`Finding` records into one :class:`LintReport`.  A finding
is *path-qualified*: ``path`` names the exact pytree leaf (keystr), jaxpr
site, or spec dim it refers to, so a failure is a worklist entry, not a
scavenger hunt.  Severities:

* ``error``   — a contract violation; the lint (and CI gate) fails.
* ``warning`` — a documented degradation on the hot path (e.g. a
  sanctioned ragged-MoE dequant, an indivisible sharding axis dropped);
  the lint passes but the item lands on the follow-up worklist.
* ``info``    — context the other passes recorded (sanctioned
  materialization under ``dense``/``ref``, replicated-by-rule leaves).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List, Optional

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    severity: str          # 'error' | 'warning' | 'info'
    pass_name: str         # 'contracts' | 'graph' | 'sharding' | 'footprint'
    rule: str              # stable rule id, e.g. 'dequant-materialization'
    path: str              # pytree keystr / jaxpr site / spec dim
    message: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity must be one of {SEVERITIES}, "
                             f"got {self.severity!r}")

    def format(self) -> str:
        return (f"[{self.severity.upper():7s}] {self.pass_name}/{self.rule} "
                f"{self.path}: {self.message}")


@dataclasses.dataclass
class LintReport:
    """Accumulated findings across passes, plus run context."""
    findings: List[Finding] = dataclasses.field(default_factory=list)
    context: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def add(self, severity: str, pass_name: str, rule: str, path: str,
            message: str) -> Finding:
        f = Finding(severity=severity, pass_name=pass_name, rule=rule,
                    path=path, message=message)
        self.findings.append(f)
        return f

    def extend(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    def by_severity(self, severity: str) -> List[Finding]:
        return [f for f in self.findings if f.severity == severity]

    @property
    def errors(self) -> List[Finding]:
        return self.by_severity("error")

    @property
    def warnings(self) -> List[Finding]:
        return self.by_severity("warning")

    @property
    def ok(self) -> bool:
        return not self.errors

    def merge(self, other: "LintReport") -> "LintReport":
        self.findings.extend(other.findings)
        self.context.update(other.context)
        return self

    def format(self, max_info: Optional[int] = None) -> str:
        lines = []
        shown_info = 0
        for f in sorted(self.findings,
                        key=lambda f: SEVERITIES.index(f.severity)):
            if f.severity == "info" and max_info is not None:
                shown_info += 1
                if shown_info > max_info:
                    continue
            lines.append(f.format())
        n_info = len(self.by_severity("info"))
        if max_info is not None and n_info > max_info:
            lines.append(f"[... {n_info - max_info} more info findings]")
        lines.append(self.summary())
        return "\n".join(lines)

    def summary(self) -> str:
        return (f"lint: {len(self.errors)} error(s), "
                f"{len(self.warnings)} warning(s), "
                f"{len(self.by_severity('info'))} info "
                f"-> {'FAIL' if self.errors else 'PASS'}")

    def to_json(self) -> str:
        return json.dumps({
            "ok": self.ok,
            "context": self.context,
            "findings": [dataclasses.asdict(f) for f in self.findings],
        }, indent=1, default=str)
