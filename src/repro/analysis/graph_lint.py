"""Hot-path graph lint: trace the serving programs, prove the compressed
wire formats stay compressed.

The BWQ efficiency claim is structural: under a packed execution backend
the compiled prefill/decode program must never hold a dequantized
full-weight-shape float tensor — dequantization belongs inside the Pallas
kernels (per-tile, in VMEM) or, for ``dense``/``ref``, is the sanctioned
in-graph strategy.  This pass traces ``ServeEngine``'s jitted entry
points to jaxprs (``jax.make_jaxpr`` over ShapeDtypeStructs — no compile,
no execute) and applies *taint tracking* from every deployed payload
input (``w_int`` / ``planes`` / ``sign``):

* ``dequant-materialization`` — a float equation output whose trailing
  two dims equal a deployed leaf's block-padded (Kp, Np) / true (K, N)
  footprint and that derives from that leaf's payload.  Error under
  ``pallas``/``bitplane``; info (sanctioned) under ``dense``/``ref``;
  warning for ragged-MoE expert leaves (the documented EP-MoE gap — see
  ROADMAP) and for packed-leaf-under-``bitplane`` fallbacks.
* ``payload-convert`` / ``payload-transpose`` — a direct
  ``convert_element_type``-to-float or ``transpose`` on a packed payload
  var outside any kernel: the start of an in-graph dequant, or a layout
  break the zero-copy kernel adapters forbid.
* ``missing-donation`` — decode state buffers not donated to the jitted
  decode step (``lower(...).args_info``): without donation every decode
  tick double-buffers the whole KV cache.

The same taint machinery audits the *KV cache* read side against the
decode-attention backend (``models.attention.paged_attn_backend``):

* ``kv-dequant-materialization`` — a float tensor with a cache leaf's
  full (T, KV, dh) footprint derives from a quantized (int8/int4) KV
  payload outside any kernel.  Error when the fused kernel was requested
  for decode (``attn_backend='fused'`` + ``fn_name='decode'`` — the
  gather fallback silently ran instead); info (sanctioned) under
  ``gather``/``ref`` and for prefill, where the gather read side is the
  design.
* ``kv-full-width-gather`` — a ``gather`` materializes the contiguous
  (B, nb, page, ...) view of a paged pool leaf (quantized or float):
  the O(max_len) ``paged_gather`` the fused kernel exists to delete.
  Same severity policy.
* ``kv-clean`` — fused decode saw KV payloads and materialized neither
  (the footprint census ``benchmarks/decode_bench.py`` asserts on).

Contiguous *float* caches are excluded as taint sources — their in-place
cache write is unavoidably a full-width float op — so only reads that
the fused kernel actually eliminates can fire.

Taint dies at ``pallas_call`` (the sanctioned kernel boundary — in-kernel
dequant is the design) and at ``dot_general``/convs (a matmul output is
an activation, not a weight), so residual-stream activations can never
false-positive against a weight footprint.  Sub-jaxprs (layer ``scan``,
``pjit``, ``cond`` branches, ``while`` bodies, custom-call wrappers) are
walked with positional invar/outvar mapping, so stacked leaves sliced by
the layer scan keep their identity inside the body.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp

from .report import Finding

_PAYLOAD_FIELDS = ("w_int", "planes", "sign")
_EXPERT_LEAF = re.compile(r"expert_(gate|up|down)")
# shape-preserving-ish prims through which a payload var stays "direct"
_PASSTHROUGH = frozenset({"squeeze", "slice", "dynamic_slice", "gather",
                          "reshape", "copy", "convert_element_type"})
# taint sinks: outputs are activations / kernel results, never weights
_SINKS = frozenset({"pallas_call", "dot_general", "conv_general_dilated",
                    "ragged_dot"})


@dataclasses.dataclass(frozen=True)
class PayloadLeaf:
    """One deployed leaf's identity + the float footprints that would
    betray its in-graph materialization."""
    path: str
    kind: str                 # 'packed' | 'bitplane'
    bits: int
    mat_shapes: frozenset     # of trailing-2-dim (rows, cols) tuples


def _deployed_types():
    from ..serve.deploy import BitplaneServingWeight, ServingWeight
    return ServingWeight, BitplaneServingWeight


def _leaf_info(path: str, leaf) -> PayloadLeaf:
    if path.startswith("[0]"):       # traced args tuple: params is arg 0
        path = path[3:]
    _, bp_t = _deployed_types()
    wbr, wbc = leaf.spec.wb_rows, leaf.spec.wb_cols
    gr, gc = leaf.scale.shape[-2], leaf.scale.shape[-1]
    kp, np_ = gr * wbr, gc * wbc
    k, n = leaf.shape[-2], leaf.shape[-1]
    shapes = {(kp, np_), (k, n)}
    if isinstance(leaf, bp_t):
        kind = "bitplane"
        shapes.add((-(-kp // 8) * 8, np_))        # byte-padded Kp8 rows
    else:
        kind = "packed"
        if leaf.bits == 4:
            shapes.add((kp + kp % 2, np_))        # nibble-unpack even rows
    return PayloadLeaf(path=path, kind=kind, bits=leaf.bits,
                       mat_shapes=frozenset(shapes))


def deployed_leaves(params: Any) -> Dict[str, Any]:
    """keystr path -> deployed leaf object, over the whole tree."""
    sw_t, bp_t = _deployed_types()
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, (sw_t, bp_t)))
    return {jax.tree_util.keystr(p): leaf for p, leaf in flat
            if isinstance(leaf, (sw_t, bp_t))}


def fallback_leaf_paths(params: Any, backend: str) -> List[str]:
    """Deployed leaves ``backend`` cannot execute natively (they fall back
    to the in-graph dense dequant dot): packed ServingWeight leaves under
    the ``bitplane`` backend.  Static — no tracing required."""
    if backend != "bitplane":
        return []
    sw_t, _ = _deployed_types()
    return [p for p, leaf in deployed_leaves(params).items()
            if isinstance(leaf, sw_t)]


def _payload_invars(jaxpr, args: tuple) -> Tuple[Dict, Optional[str]]:
    """Map jaxpr invars to the PayloadLeaf they carry (w_int/planes/sign).

    ``args`` is the exact tuple the jaxpr was traced from — its flattened
    leaves correspond 1:1, in order, to ``jaxpr.jaxpr.invars``."""
    owners = deployed_leaves(args)
    flat, _ = jax.tree_util.tree_flatten_with_path(args)
    invars = jaxpr.jaxpr.invars
    if len(flat) != len(invars):
        return {}, (f"cannot map payload leaves to jaxpr inputs: "
                    f"{len(flat)} arg leaves vs {len(invars)} invars")
    payload = {}
    for (path, _leaf), var in zip(flat, invars):
        last = path[-1]
        name = getattr(last, "name", None)
        if name not in _PAYLOAD_FIELDS:
            continue
        owner_path = jax.tree_util.keystr(path[:-1])
        owner = owners.get(owner_path)
        if owner is not None:
            payload[var] = _leaf_info(owner_path, owner)
    return payload, None


def _sub_jaxprs(eqn):
    """[(sub jaxpr, invar pairs, outvar pairs)] for container primitives.

    ``pallas_call`` also carries a ``jaxpr`` param but is deliberately NOT
    recursed: in-kernel dequantization is the sanctioned design."""
    name = eqn.primitive.name
    p = eqn.params
    if name == "pallas_call":
        return []
    subs = []
    if name == "cond":
        for br in p.get("branches", ()):
            jx = br.jaxpr
            subs.append((jx, list(zip(jx.invars, eqn.invars[1:])),
                         list(zip(jx.outvars, eqn.outvars))))
        return subs
    if name == "while":
        body = p["body_jaxpr"].jaxpr
        outer = eqn.invars[p["cond_nconsts"]:]
        subs.append((body, list(zip(body.invars, outer)),
                     list(zip(body.outvars, eqn.outvars))))
        return subs
    sub = None
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in p:
            sub = p[key]
            break
    if sub is None:
        return []
    jx = sub.jaxpr if hasattr(sub, "jaxpr") else sub
    if len(jx.invars) == len(eqn.invars):        # scan/pjit: positional
        subs.append((jx, list(zip(jx.invars, eqn.invars)),
                     list(zip(jx.outvars, eqn.outvars))))
    return subs


def _is_var(v) -> bool:
    return not hasattr(v, "val")                 # Literal carries .val


def _float_out(v) -> bool:
    try:
        return jnp.issubdtype(v.aval.dtype, jnp.floating)
    except Exception:
        return False


class _Walk:
    """One traced function's walk state: findings, dedup, severity policy."""

    def __init__(self, fn_name: str, backend: str):
        self.fn = fn_name
        self.backend = backend
        self.findings: List[Finding] = []
        self._seen: Set[tuple] = set()
        from ..models.moe import GROUPED_IMPL
        self._ragged_moe = GROUPED_IMPL.get("impl") == "ragged"

    def _severity(self, leaf: PayloadLeaf, rule: str) -> Tuple[str, str]:
        """(severity, rule) under the backend's materialization policy."""
        if self.backend == "bitplane" and leaf.kind == "packed":
            return "warning", "bitplane-dense-fallback"
        if self.backend in ("pallas", "bitplane"):
            if self._ragged_moe and _EXPERT_LEAF.search(leaf.path):
                return "warning", "sanctioned-moe-dequant"
            return "error", rule
        return "info", "sanctioned-dequant"

    def emit(self, leaf: PayloadLeaf, rule: str, message: str) -> None:
        severity, rule = self._severity(leaf, rule)
        key = (rule, leaf.path)
        if key in self._seen:
            return
        self._seen.add(key)
        self.findings.append(Finding(
            severity=severity, pass_name="graph", rule=rule,
            path=f"{self.fn}:{leaf.path}", message=message))

    def walk(self, jaxpr, payload: Dict, taint: Dict) -> None:
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            in_payload = [payload[v] for v in eqn.invars
                          if _is_var(v) and v in payload]
            in_taint: Set[PayloadLeaf] = set()
            for v in eqn.invars:
                if _is_var(v):
                    in_taint |= taint.get(v, set())
            subs = _sub_jaxprs(eqn)
            if subs:
                for jx, inmap, outmap in subs:
                    sub_p = {sv: payload[ov] for sv, ov in inmap
                             if _is_var(ov) and ov in payload}
                    sub_t = {sv: set(taint.get(ov, set()))
                             for sv, ov in inmap if _is_var(ov)}
                    self.walk(jx, sub_p, sub_t)
                    for sv, ov in outmap:
                        if _is_var(sv) and _is_var(ov):
                            got = set(sub_t.get(sv, set()))
                            if sv in sub_p:
                                got.add(sub_p[sv])
                            if got:
                                taint.setdefault(ov, set()).update(got)
                continue
            if name in _SINKS:
                continue                         # activations, not weights
            if in_payload:
                if name == "convert_element_type" \
                        and any(_float_out(ov) for ov in eqn.outvars):
                    for leaf in in_payload:
                        self.emit(leaf, "payload-convert",
                                  f"convert_element_type to "
                                  f"{eqn.outvars[0].aval.dtype} on packed "
                                  f"payload ({leaf.kind}, int{leaf.bits}) "
                                  f"outside any kernel")
                if name == "transpose":
                    for leaf in in_payload:
                        self.emit(leaf, "payload-transpose",
                                  f"transpose on packed payload "
                                  f"({leaf.kind}): breaks the zero-copy "
                                  f"kernel layout contract")
                if name in _PASSTHROUGH:
                    for ov in eqn.outvars:
                        payload[ov] = in_payload[0]
            if not (in_taint or in_payload):
                continue
            out_taint = in_taint | set(in_payload)
            for ov in eqn.outvars:
                taint.setdefault(ov, set()).update(out_taint)
                if not _float_out(ov):
                    continue
                shape = tuple(getattr(ov.aval, "shape", ()))
                if len(shape) < 2:
                    continue
                t2 = shape[-2:]
                for leaf in out_taint:
                    if t2 in leaf.mat_shapes:
                        self.emit(
                            leaf, "dequant-materialization",
                            f"float {ov.aval.dtype} tensor {shape} "
                            f"materializes the {leaf.kind} int{leaf.bits} "
                            f"leaf's {t2} weight footprint in-graph "
                            f"(eqn '{name}') under backend="
                            f"{self.backend!r}")


# ---------------------------------------------------------------------------
# KV-cache read-side lint (decode-attention backend)
# ---------------------------------------------------------------------------

_KV_FIELDS = ("k", "v")
# the decode-step scatter/slice writes preserve a cache leaf's identity
# (operand 0 in, same-shape buffer out) — the read side must still see
# the written pool as *the* payload for the full-width-gather rule
_KV_PASSTHROUGH = _PASSTHROUGH | {"scatter", "dynamic_update_slice"}


@dataclasses.dataclass(frozen=True)
class KVLeaf:
    """One KV cache leaf's identity + the footprints that betray a
    full-width read outside the fused kernel."""
    path: str
    bits: int                 # 8 / 4 (quantized-at-rest) or 32 (float)
    paged: bool
    kv: int                   # KV heads
    dh: int                   # dequantized head dim (2x stored for int4)
    tail3: tuple              # stored trailing dims (page|T, KV, dh_s)


def _path_keys(path) -> List[Optional[str]]:
    return [getattr(e, "key", getattr(e, "name", None)) for e in path]


def _kv_payload_invars(jaxpr, args: tuple) -> Dict:
    """Map jaxpr invars to the KVLeaf they carry (cache ``k``/``v``).

    Contiguous float caches are skipped: their in-place write is an
    unavoidable full-width float op, so they cannot be lint sources."""
    flat, _ = jax.tree_util.tree_flatten_with_path(args)
    invars = jaxpr.jaxpr.invars
    if len(flat) != len(invars):
        return {}
    payload = {}
    for (path, leaf), var in zip(flat, invars):
        keys = _path_keys(path)
        if not keys or keys[-1] not in _KV_FIELDS or "cache" not in keys:
            continue
        shape = tuple(getattr(leaf, "shape", ()))
        if len(shape) < 3:
            continue                             # recurrent state rows
        dt = jnp.dtype(leaf.dtype)
        bits = {jnp.dtype(jnp.int8): 8, jnp.dtype(jnp.uint8): 4}.get(dt, 32)
        paged = "pages" in keys
        if bits == 32 and (not paged
                           or not jnp.issubdtype(dt, jnp.floating)):
            continue
        dh = shape[-1] * 2 if bits == 4 else shape[-1]
        payload[var] = KVLeaf(path=jax.tree_util.keystr(path), bits=bits,
                              paged=paged, kv=shape[-2], dh=dh,
                              tail3=shape[-3:])
    return payload


class _KVWalk:
    """Taint walk over the KV-cache read side (severity keyed on the
    decode-attention backend, not the matmul backend)."""

    def __init__(self, fn_name: str, attn_backend: str):
        self.fn = fn_name
        self.attn = attn_backend
        self.findings: List[Finding] = []
        self._seen: Set[tuple] = set()

    def _emit(self, leaf: KVLeaf, rule: str, sanctioned: str,
              message: str) -> None:
        if self.attn == "fused" and self.fn == "decode":
            severity = "error"          # fused requested, fallback ran
        else:
            severity, rule = "info", sanctioned
        key = (rule, leaf.path)
        if key in self._seen:
            return
        self._seen.add(key)
        self.findings.append(Finding(
            severity=severity, pass_name="graph", rule=rule,
            path=f"{self.fn}:{leaf.path}", message=message))

    def walk(self, jaxpr, payload: Dict, taint: Dict) -> None:
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            in_payload = [payload[v] for v in eqn.invars
                          if _is_var(v) and v in payload]
            in_taint: Set[KVLeaf] = set()
            for v in eqn.invars:
                if _is_var(v):
                    in_taint |= taint.get(v, set())
            subs = _sub_jaxprs(eqn)
            if subs:
                for jx, inmap, outmap in subs:
                    sub_p = {sv: payload[ov] for sv, ov in inmap
                             if _is_var(ov) and ov in payload}
                    sub_t = {sv: set(taint.get(ov, set()))
                             for sv, ov in inmap if _is_var(ov)}
                    self.walk(jx, sub_p, sub_t)
                    for sv, ov in outmap:
                        if _is_var(sv) and _is_var(ov):
                            got = set(sub_t.get(sv, set()))
                            if got:
                                taint.setdefault(ov, set()).update(got)
                continue
            if name == "gather":
                for v in eqn.invars:
                    if not (_is_var(v) and v in payload
                            and payload[v].paged):
                        continue
                    leaf = payload[v]
                    for ov in eqn.outvars:
                        osh = tuple(ov.aval.shape)
                        if (len(osh) == len(v.aval.shape) + 1
                                and osh[-3:] == tuple(leaf.tail3)):
                            self._emit(
                                leaf, "kv-full-width-gather",
                                "sanctioned-kv-gather",
                                f"gather materializes the contiguous "
                                f"{osh} view of the paged KV pool "
                                f"(O(max_len) per decode step) under "
                                f"attn_backend={self.attn!r}")
            if name in _SINKS:
                continue                 # pallas_call: in-kernel dequant
            if in_payload and name in _KV_PASSTHROUGH:
                src = eqn.invars[0]      # operand 0 carries the identity
                if _is_var(src) and src in payload:
                    for ov in eqn.outvars:
                        payload[ov] = payload[src]
            quant_in = {l for l in in_payload if l.bits < 32}
            if not (in_taint or quant_in):
                continue
            out_taint = in_taint | quant_in
            for ov in eqn.outvars:
                taint.setdefault(ov, set()).update(out_taint)
                if not _float_out(ov):
                    continue
                osh = tuple(getattr(ov.aval, "shape", ()))
                if len(osh) < 3:
                    continue
                for leaf in out_taint:
                    if osh[-2:] == (leaf.kv, leaf.dh) \
                            and osh[-3] >= leaf.tail3[0]:
                        self._emit(
                            leaf, "kv-dequant-materialization",
                            "sanctioned-kv-dequant",
                            f"float {ov.aval.dtype} tensor {osh} "
                            f"materializes the int{leaf.bits} KV cache "
                            f"leaf's full (T, KV, dh) tree outside any "
                            f"kernel (eqn {name!r}) under attn_backend="
                            f"{self.attn!r}")


def lint_traced_fn(fn, args: tuple, *, fn_name: str, backend: str,
                   attn_backend: str = "gather") -> List[Finding]:
    """Trace ``fn(*args)`` under ``backend``/``attn_backend`` and lint
    the jaxpr (weight materialization + KV-cache read side).

    ``args`` may mix concrete arrays, ShapeDtypeStructs and deployed
    dataclasses; the trace is abstract (no compile, no execute)."""
    from ..models.attention import paged_attn_backend
    from ..models.common import matmul_backend

    def wrapped(*a):
        with matmul_backend(backend), paged_attn_backend(attn_backend):
            return fn(*a)

    findings: List[Finding] = []
    try:
        jaxpr = jax.make_jaxpr(wrapped)(*args)
    except Exception as e:
        findings.append(Finding(
            severity="error", pass_name="graph", rule="trace-failure",
            path=fn_name,
            message=f"tracing failed ({type(e).__name__}: {e})"))
        return findings
    payload, problem = _payload_invars(jaxpr, args)
    if problem:
        findings.append(Finding(severity="error", pass_name="graph",
                                rule="invar-mapping", path=fn_name,
                                message=problem))
        return findings
    if not payload:
        findings.append(Finding(
            severity="info", pass_name="graph", rule="no-payload",
            path=fn_name,
            message="no deployed packed leaves reach this function; "
                    "materialization lint is vacuous"))
    else:
        w = _Walk(fn_name, backend)
        w.walk(jaxpr.jaxpr, dict(payload), {v: set() for v in payload})
        if not w.findings:
            findings.append(Finding(
                severity="info", pass_name="graph", rule="clean",
                path=fn_name,
                message=f"{len(payload)} packed payload inputs; no "
                        f"in-graph materialization under backend="
                        f"{backend!r}"))
        findings += w.findings
    kv_payload = _kv_payload_invars(jaxpr, args)
    if kv_payload:
        kw = _KVWalk(fn_name, attn_backend)
        kw.walk(jaxpr.jaxpr, dict(kv_payload),
                {v: set() for v in kv_payload})
        if not kw.findings and attn_backend == "fused" \
                and fn_name == "decode":
            findings.append(Finding(
                severity="info", pass_name="graph", rule="kv-clean",
                path=fn_name,
                message=f"{len(kv_payload)} KV cache payload inputs; "
                        f"fused decode materializes neither the "
                        f"contiguous KV view nor the f32 KV tree"))
        findings += kw.findings
    return findings


# ---------------------------------------------------------------------------
# donation check
# ---------------------------------------------------------------------------

def check_decode_donation(engine, tokens, state, index) -> List[Finding]:
    """Verify the decode state is donated to the jitted decode step.

    Uses ``Lowered.args_info`` (per-leaf ``.donated``) — a lowering-level
    fact, independent of whether the platform honors donation."""
    findings: List[Finding] = []
    try:
        lowered = engine._decode_j.lower(engine.params, tokens, state, index)
        state_info = lowered.args_info[0][2]
    except Exception as e:
        findings.append(Finding(
            severity="error", pass_name="graph", rule="donation-lowering",
            path="decode", message=f"could not lower decode to inspect "
                                   f"donation ({type(e).__name__}: {e})"))
        return findings
    flat, _ = jax.tree_util.tree_flatten_with_path(state_info)
    missing = [jax.tree_util.keystr(p) for p, a in flat if not a.donated]
    if missing:
        findings.append(Finding(
            severity="error", pass_name="graph", rule="missing-donation",
            path="decode:state",
            message=f"{len(missing)}/{len(flat)} decode-state buffers are "
                    f"not donated (double-buffered KV cache per tick): "
                    f"{missing[:5]}"))
    else:
        findings.append(Finding(
            severity="info", pass_name="graph", rule="donation-ok",
            path="decode:state",
            message=f"all {len(flat)} decode-state buffers donated"))
    return findings
