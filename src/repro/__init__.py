"""Block-Wise Mixed-Precision Quantization (BWQ) reproduction.

Subpackages: core (quantization math), models (LM families), dist
(sharding + HLO analysis), hw (ReRAM accelerator simulator), kernels,
train, serve, launch, configs, data, optim, ckpt.
"""
