"""Fault-tolerant checkpointing with atomic commit + elastic resharding.

Layout: ``<dir>/step_<N>/{arrays.npz, META}``.  Writes go to a temp dir and
are renamed into place only after fsync — a crash mid-write never corrupts
the latest checkpoint.  Restore maps saved arrays onto a *template* pytree
(from ``api.abstract_params()``) by path, then (optionally) device_puts each
leaf with the sharding of the *currently live* mesh — which is what lets a
job restart on a different mesh shape (elastic scaling).  Static pytree
structure (QuantizedTensor specs etc.) comes from the template, so only
array data lives on disk.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def _sanitize(key: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.]", "_", key)


def save_tree(tree: Any, path: str, extra_meta: Optional[Dict] = None):
    """Atomic write of all array leaves of ``tree`` to ``path``."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays, manifest = {}, {}
    for k, v in flat.items():
        sk = _sanitize(k)
        manifest[k] = sk
        arrays[sk] = np.asarray(jax.device_get(v))
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "META"), "w") as f:
        json.dump({"manifest": manifest, "extra": extra_meta or {}}, f)
    # fsync the directory contents before the atomic rename
    for name in os.listdir(tmp):
        fd = os.open(os.path.join(tmp, name), os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def restore_tree(template: Any, path: str, mesh=None,
                 shardings: Any = None) -> Any:
    """Load arrays onto ``template``'s structure; reshard onto ``mesh``."""
    with open(os.path.join(path, "META")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    manifest = meta["manifest"]

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(shardings)[0]
    leaves = []
    for i, (p, leaf) in enumerate(flat):
        k = jax.tree_util.keystr(p)
        arr = data[manifest[k]]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Rolling checkpoints + async save thread + latest-step discovery."""

    def __init__(self, directory: str, keep: int = 3, use_async: bool = True):
        self.dir = directory
        self.keep = keep
        self.use_async = use_async
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def _step_dirs(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "META")):
                out.append((int(m.group(1)), os.path.join(self.dir, name)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        dirs = self._step_dirs()
        return dirs[-1][0] if dirs else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, extra_meta: Optional[Dict] = None):
        self.wait()
        # device_get synchronously (cheap vs. training step), write async
        tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                      tree)
        path = os.path.join(self.dir, f"step_{step}")

        def work():
            save_tree(tree, path, extra_meta)
            self._gc()

        if self.use_async:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def _gc(self):
        dirs = self._step_dirs()
        for _, p in dirs[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)

    def restore_latest(self, template: Any, mesh=None, shardings=None):
        self.wait()
        step = self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "META")) as f:
            extra = json.load(f)["extra"]
        tree = restore_tree(template, path, mesh, shardings)
        return (step, extra), tree
