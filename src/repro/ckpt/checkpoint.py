"""Crash-safe sharded checkpointing with elastic restore.

Layout (format 2)::

    <dir>/step_<N>/
        META                          # JSON: manifest + mesh + extra
        shard_00000-of-0000M.npz      # one file per (emulated) host

``save_tree`` splits every array leaf into per-mesh-coordinate chunks by
its fitted PartitionSpec and writes each chunk exactly once, into the
shard file of the host that owns it (hosts are enumerated over the mesh
axes any leaf actually uses; with no mesh there is a single shard file).
META records, per leaf, the true shape, dtype, spec entries, and the
saving mesh's axis sizes — so ``restore_tree`` re-assembles each leaf
from the shard manifests and re-places it onto a *different* live mesh
(elastic scaling), never needing the saving topology.

Commit protocol (crash-safe at every point):

1. write everything into a uniquely named ``<path>.tmp.<nonce>`` dir,
   ``fsync`` each file and the tmp dir itself;
2. if ``<path>`` exists, atomically move it aside to
   ``<path>.old.<nonce>`` (never deleted before the new data is live);
3. ``rename(tmp, path)`` and ``fsync`` the parent directory so the
   rename itself is durable;
4. only then delete the old copy.

A crash between (2) and (3) leaves both the complete tmp dir and the
old copy on disk — no window ever destroys the only copy of a step.
``CheckpointManager._gc`` sweeps stale ``.tmp.*`` / ``.old.*`` debris.

Restore maps saved arrays onto a *template* pytree by path; a
template/manifest disagreement raises :class:`CheckpointMismatchError`
listing the missing and extra keys (``partial=True`` opts into keeping
template values for missing keys and ignoring extras — the schema-drift
escape hatch).  Static pytree structure (QuantizedTensor specs etc.)
comes from the template, so only array data lives on disk.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import uuid
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import numpy as np

CKPT_FORMAT = 2


def _flatten_with_paths(tree, is_leaf=None) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def _sanitize(key: str) -> str:
    """Collision-free npz key: every char outside [A-Za-z0-9.] becomes
    ``_xx`` (two hex digits), and ``_`` itself escapes to ``_5f`` — an
    injective encoding, so distinct tree paths (``['a b']`` vs
    ``['a_b']``) can never share an npz entry."""
    return re.sub(r"[^A-Za-z0-9.]",
                  lambda m: f"_{ord(m.group(0)):02x}", key)


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_tree(tmp: str) -> None:
    for name in os.listdir(tmp):
        fd = os.open(os.path.join(tmp, name), os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
    _fsync_dir(tmp)


def _commit_dir(tmp: str, path: str) -> None:
    """Atomically make ``tmp`` live at ``path`` (see module docstring)."""
    old = None
    if os.path.exists(path):
        old = f"{path}.old.{uuid.uuid4().hex[:8]}"
        os.rename(path, old)
    os.rename(tmp, path)
    _fsync_dir(os.path.dirname(os.path.abspath(path)) or ".")
    if old is not None:
        shutil.rmtree(old, ignore_errors=True)


# --------------------------------------------------------------------------
# sharded layout
# --------------------------------------------------------------------------

def _spec_entries(ps) -> List[Any]:
    """JSON-able spec: one entry per dim — None or a list of axis names."""
    out: List[Any] = []
    for entry in tuple(ps):
        if entry is None:
            out.append(None)
        else:
            out.append(list(entry) if isinstance(entry, tuple)
                       else [entry])
    return out


def _leaf_specs(flat: Dict[str, Any], mesh, specs) -> Dict[str, List[Any]]:
    """Fitted, divisible (pad=False) spec entries per leaf keystr.

    Chunking must tile each leaf exactly, so saving always fits with the
    legacy drop rule — a padded-sharded *placement* still saves its true
    (unpadded) array, which is what elastic restore wants."""
    from ..dist.sharding import _leaf_spec, fit_spec, use_mesh
    if mesh is None:
        return {k: [None] * np.ndim(v) for k, v in flat.items()}
    if specs is None:
        # parameter path rules, keyed by the original tree keystr
        with use_mesh(mesh):
            return {k: _spec_entries(_leaf_spec(k, v, pad=False))
                    for k, v in flat.items()}
    spec_flat = _flatten_with_paths(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    out = {}
    for k, v in flat.items():
        ps = spec_flat.get(k)
        shape = tuple(np.shape(v))
        if ps is None:
            out[k] = [None] * len(shape)
        else:
            out[k] = _spec_entries(fit_spec(ps, shape, mesh, label=k,
                                            pad=False))
    return out


def _used_axes(leaf_specs: Dict[str, List[Any]]) -> List[str]:
    axes: List[str] = []
    for entries in leaf_specs.values():
        for entry in entries:
            for a in entry or ():
                if a not in axes:
                    axes.append(a)
    return sorted(axes)


def _host_grid(mesh_axes: Dict[str, int],
               axes: Sequence[str]) -> List[Dict[str, int]]:
    """One emulated host per coordinate tuple over ``axes`` (the mesh
    axes any leaf spec uses).  A single-process save stands in for every
    host of a real fleet; on a multi-process runtime each process would
    write exactly its own coordinates' file."""
    hosts: List[Dict[str, int]] = [{}]
    for a in axes:
        hosts = [dict(h, **{a: i}) for h in hosts
                 for i in range(mesh_axes.get(a, 1))]
    return hosts


def _chunk_slices(shape: Sequence[int], entries: List[Any],
                  mesh_axes: Dict[str, int],
                  coords: Dict[str, int]) -> Optional[Tuple[slice, ...]]:
    """The sub-slice of a leaf that the host at ``coords`` owns, or None
    when another host owns the (replicated-dim) copy.  Ownership: the
    host whose coordinates are 0 on every axis the leaf does NOT shard
    over writes the chunk; sharded dims index by the host's coords."""
    sl: List[slice] = []
    used: set = set()
    for dim, entry in zip(shape, entries):
        if not entry:
            sl.append(slice(None))
            continue
        size = 1
        idx = 0
        for a in entry:
            idx = idx * mesh_axes[a] + coords[a]
            size *= mesh_axes[a]
            used.add(a)
        step = dim // size
        sl.append(slice(idx * step, (idx + 1) * step))
    for a, c in coords.items():
        if a not in used and c != 0:
            return None
    return tuple(sl)


def save_tree(tree: Any, path: str, extra_meta: Optional[Dict] = None,
              mesh=None, specs: Any = None):
    """Atomic sharded write of all array leaves of ``tree`` to ``path``.

    With ``mesh`` (defaults to the single-shard layout when None), every
    leaf is chunked by its fitted PartitionSpec — ``specs`` (a matching
    tree of specs) overrides the parameter rules — and each chunk lands
    in the shard file of the host that owns it.  META carries the spec +
    mesh-shape metadata that makes restore topology-independent."""
    flat = _flatten_with_paths(tree)
    flat = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    mesh_axes = dict(getattr(mesh, "shape", {}) or {}) if mesh is not None \
        else {}
    leaf_specs = _leaf_specs(flat, mesh, specs)
    axes = _used_axes(leaf_specs)
    hosts = _host_grid(mesh_axes, axes)
    n = len(hosts)

    manifest: Dict[str, Dict[str, Any]] = {}
    shard_arrays: List[Dict[str, np.ndarray]] = [{} for _ in range(n)]
    seen: Dict[str, str] = {}
    for k, v in flat.items():
        sk = _sanitize(k)
        if sk in seen:                      # _sanitize is injective, so
            raise ValueError(               # this is pure belt-and-braces
                f"sanitized key collision: {k!r} and {seen[sk]!r} both "
                f"map to {sk!r}")
        seen[sk] = k
        entries = leaf_specs[k]
        manifest[k] = {"key": sk, "shape": list(v.shape),
                       "dtype": str(v.dtype), "spec": entries}
        for h, coords in enumerate(hosts):
            sl = _chunk_slices(v.shape, entries, mesh_axes, coords)
            if sl is not None:
                shard_arrays[h][sk] = v[sl]

    tmp = f"{path}.tmp.{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp)
    for h in range(n):
        np.savez(os.path.join(tmp, f"shard_{h:05d}-of-{n:05d}.npz"),
                 **shard_arrays[h])
    with open(os.path.join(tmp, "META"), "w") as f:
        json.dump({"format": CKPT_FORMAT, "manifest": manifest,
                   "mesh_axes": mesh_axes, "shard_axes": axes,
                   "n_shards": n,
                   "hosts": [[hst.get(a, 0) for a in axes]
                             for hst in hosts],
                   "extra": extra_meta or {}}, f)
    _fsync_tree(tmp)
    _commit_dir(tmp, path)


class CheckpointMismatchError(ValueError):
    """Template and checkpoint manifest disagree on the set of leaves.

    ``missing`` — template keys the checkpoint does not hold;
    ``extra`` — checkpoint keys the template does not expect."""

    def __init__(self, path: str, missing: List[str], extra: List[str]):
        self.path = path
        self.missing = list(missing)
        self.extra = list(extra)
        lines = [f"checkpoint {path!r} does not match the restore template "
                 f"({len(missing)} missing, {len(extra)} extra):"]
        for k in missing[:8]:
            lines.append(f"  missing from checkpoint: {k}")
        for k in extra[:8]:
            lines.append(f"  extra in checkpoint:     {k}")
        if len(missing) > 8 or len(extra) > 8:
            lines.append("  ...")
        lines.append("pass partial=True to keep template values for "
                     "missing keys and ignore extras")
        super().__init__("\n".join(lines))


class CheckpointReader:
    """Lazy reader over a (sharded or legacy) checkpoint directory.

    Assembles one leaf at a time from its shard chunks — the streaming
    primitive behind both :func:`restore_tree` and the direct
    checkpoint→serving deployment, which must never materialize the
    whole f32 tree on one host."""

    def __init__(self, path: str):
        self.path = path
        with open(os.path.join(path, "META")) as f:
            self.meta = json.load(f)
        self.extra = self.meta.get("extra", {})
        self._files: Dict[str, Any] = {}
        if self.meta.get("format", 1) >= 2:
            self.manifest: Dict[str, Dict[str, Any]] = self.meta["manifest"]
            self._legacy = False
        else:                                 # v1: monolithic arrays.npz
            self.manifest = {k: {"key": sk} for k, sk
                             in self.meta["manifest"].items()}
            self._legacy = True

    def keys(self) -> List[str]:
        return list(self.manifest)

    def _file(self, name: str):
        if name not in self._files:
            self._files[name] = np.load(os.path.join(self.path, name))
        return self._files[name]

    def close(self) -> None:
        for f in self._files.values():
            f.close()
        self._files.clear()

    def _hosts(self) -> List[Dict[str, int]]:
        axes = self.meta["shard_axes"]
        return [dict(zip(axes, c)) for c in self.meta["hosts"]]

    def read(self, key: str) -> np.ndarray:
        """Assemble one leaf from its shard chunks (or the legacy npz)."""
        ent = self.manifest[key]
        if self._legacy:
            return self._file("arrays.npz")[ent["key"]]
        mesh_axes = self.meta["mesh_axes"]
        entries = ent["spec"]
        shape = tuple(ent["shape"])
        out: Optional[np.ndarray] = None
        n = self.meta["n_shards"]
        for h, coords in enumerate(self._hosts()):
            sl = _chunk_slices(shape, entries, mesh_axes, coords)
            if sl is None:
                continue
            chunk = self._file(f"shard_{h:05d}-of-{n:05d}.npz")[ent["key"]]
            if out is None:
                if all(s == slice(None) for s in sl):
                    out = chunk           # replicated leaf: single owner
                    break
                out = np.empty(shape, dtype=ent["dtype"])
            out[sl] = chunk
        if out is None:
            raise KeyError(f"{key!r} has no chunks in {self.path!r}")
        return out

    def iter_arrays(self) -> Iterator[Tuple[str, np.ndarray]]:
        for k in self.manifest:
            yield k, self.read(k)


def restore_tree(template: Any, path: str, mesh=None,
                 shardings: Any = None, partial: bool = False) -> Any:
    """Load arrays onto ``template``'s structure; reshard onto ``mesh``.

    Leaves assemble one at a time from the shard manifests, then each is
    ``device_put`` with its sharding under the *currently live* mesh —
    saving under a 1-host mesh and restoring under a 16-host one (or
    vice versa) is the supported elastic path.  A template/manifest
    key-set mismatch raises :class:`CheckpointMismatchError` unless
    ``partial=True`` (missing keys keep their template values, extra
    checkpoint keys are skipped)."""
    reader = CheckpointReader(path)
    try:
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        keys = [jax.tree_util.keystr(p) for p, _ in flat]
        missing = [k for k in keys if k not in reader.manifest]
        extra = [k for k in reader.manifest if k not in set(keys)]
        if (missing or extra) and not partial:
            raise CheckpointMismatchError(path, missing, extra)

        shard_flat = None
        if shardings is not None:
            shard_flat = jax.tree_util.tree_flatten(shardings)[0]
        elif mesh is not None:
            from ..dist.sharding import param_pspecs, use_mesh
            from jax.sharding import NamedSharding
            with use_mesh(mesh):
                spec_tree = param_pspecs(template, pad=False)
            shard_flat = [NamedSharding(mesh, s) for s in
                          jax.tree_util.tree_leaves(
                              spec_tree,
                              is_leaf=lambda x: isinstance(
                                  x, jax.sharding.PartitionSpec))]

        leaves = []
        for i, ((p, leaf), k) in enumerate(zip(flat, keys)):
            if k not in reader.manifest:
                leaves.append(leaf)          # partial: keep template value
                continue
            arr = reader.read(k)
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            if shard_flat is not None:
                leaves.append(jax.device_put(arr, shard_flat[i]))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)
    finally:
        reader.close()


class CheckpointManager:
    """Rolling checkpoints + async save thread + latest-step discovery.

    A failed async save is never silent: the exception is captured and
    re-raised from the next :meth:`wait` or :meth:`save` call."""

    def __init__(self, directory: str, keep: int = 3, use_async: bool = True):
        self.dir = directory
        self.keep = keep
        self.use_async = use_async
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    def _step_dirs(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "META")):
                out.append((int(m.group(1)), os.path.join(self.dir, name)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        dirs = self._step_dirs()
        return dirs[-1][0] if dirs else None

    def wait(self):
        """Join any in-flight async save; re-raise its failure, if any."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                f"async checkpoint save failed: {err!r}") from err

    def save(self, step: int, tree: Any, extra_meta: Optional[Dict] = None,
             mesh=None, specs: Any = None):
        self.wait()
        # device_get synchronously (cheap vs. training step), write async
        tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                      tree)
        path = os.path.join(self.dir, f"step_{step}")

        def work():
            try:
                save_tree(tree, path, extra_meta, mesh=mesh, specs=specs)
                self._gc()
            except BaseException as e:       # surfaced by the next wait()
                self._error = e

        if self.use_async:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            if self._error is not None:
                self.wait()                  # re-raise immediately

    def _gc(self):
        dirs = self._step_dirs()
        # NOT dirs[:-keep]: keep=0 must prune everything; clamp so fewer
        # dirs than ``keep`` prunes nothing (negative slice bites the tail)
        cut = max(0, len(dirs) - self.keep)
        for _, p in dirs[:cut]:
            shutil.rmtree(p, ignore_errors=True)
        for name in os.listdir(self.dir):    # crash debris from _commit_dir
            if re.search(r"\.(tmp|old)\.[0-9a-f]{8}$", name):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    def restore_latest(self, template: Any, mesh=None, shardings=None,
                       partial: bool = False):
        self.wait()
        step = self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "META")) as f:
            extra = json.load(f)["extra"]
        tree = restore_tree(template, path, mesh, shardings,
                            partial=partial)
        return (step, extra), tree
