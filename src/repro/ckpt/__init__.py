from .checkpoint import (CheckpointManager, CheckpointMismatchError,
                         CheckpointReader, restore_tree, save_tree)
