from .checkpoint import CheckpointManager, restore_tree, save_tree
