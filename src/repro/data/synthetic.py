"""Deterministic, index-addressable synthetic data.

Every global batch is a pure function of (seed, step) — any host can
regenerate any batch without coordination.  That property is what makes the
elastic-restart and straggler-replacement stories work: a replacement host
joining at step N needs no data replay, it just computes batch(N)
(DESIGN.md §4).

The LM stream has planted bigram structure (a peaked random transition
table) so cross-entropy genuinely decreases under training and
quantization-vs-quality trade-offs are measurable offline.  The CIFAR-like
stream plants class templates + noise for the paper's CNN experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    branching: int = 4          # candidate successors per token

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.succ = rng.integers(0, self.vocab,
                                 size=(self.vocab, self.branching))

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        """Pure function of step: (tokens, labels) with labels = next token."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        start = jax.random.randint(k1, (self.batch,), 0, self.vocab)
        choices = jax.random.randint(k2, (self.batch, self.seq_len + 1),
                                     0, self.branching)
        succ = jnp.asarray(self.succ)

        def walk(tok, choice):
            nxt = succ[tok, choice]
            return nxt, nxt

        def roll(s, ch):
            _, seq = jax.lax.scan(walk, s, ch)
            return seq

        seq = jax.vmap(roll)(start, choices)              # (B, S+1)
        return {"tokens": seq[:, :-1].astype(jnp.int32),
                "labels": seq[:, 1:].astype(jnp.int32)}

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class SyntheticCIFAR:
    num_classes: int = 10
    image: int = 32
    batch: int = 128
    seed: int = 0
    noise: float = 0.6

    def __post_init__(self):
        rng = np.random.default_rng(self.seed + 1)
        self.templates = rng.normal(
            size=(self.num_classes, self.image, self.image, 3)).astype("f4")

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        labels = jax.random.randint(k1, (self.batch,), 0, self.num_classes)
        base = jnp.asarray(self.templates)[labels]
        noise = jax.random.normal(k2, base.shape) * self.noise
        return {"images": base + noise, "labels": labels}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def lm_batch_for(cfg, cell, step: int = 0, seed: int = 0):
    """Concrete batch matching a ModelAPI train_batch_spec (smoke tests)."""
    gen = SyntheticLM(cfg.vocab, cell.seq_len, cell.global_batch, seed)
    b = gen.batch_at(step)
    if cfg.family == "vlm":
        tv = cfg.vision_tokens
        st = cell.seq_len - tv
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 7), step)
        b = {"tokens": b["tokens"][:, :st], "labels": b["labels"][:, :st],
             "vision_embeds": jax.random.normal(
                 key, (cell.global_batch, tv, cfg.d_model), jnp.float32) * .1}
    if cfg.is_encdec:
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 9), step)
        b = {"tokens": b["tokens"], "labels": b["labels"],
             "frames": jax.random.normal(
                 key, (cell.global_batch, cell.seq_len, cfg.d_model),
                 jnp.float32) * 0.1}
    return b


def make_lm_pipeline(cfg, seq_len: int, batch: int, seed: int = 0,
                     start_step: int = 0):
    """Resumable iterator (checkpoint stores the step; restart is exact)."""
    gen = SyntheticLM(cfg.vocab, seq_len, batch, seed)
    step = start_step
    while True:
        yield step, gen.batch_at(step)
        step += 1
