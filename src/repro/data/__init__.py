from .synthetic import (SyntheticCIFAR, SyntheticLM, lm_batch_for,
                        make_lm_pipeline)
