"""Shared benchmark harness: train small BWQ-A / BSQ / float models on the
synthetic datasets so every paper table is computed from *actual trained
quantization state*, not canned numbers.

BSQ is exactly BWQ-A with one whole-layer block (BlockingSpec(0, 0)) —
the paper's own framing of the baseline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY
from repro.data import SyntheticCIFAR, SyntheticLM, make_lm_pipeline
from repro.models.api import build
from repro.models.cnn import cnn_loss, resnet_init, resnet_apply, vgg_init, vgg_apply
from repro.models.common import QuantConfig
from repro.optim import adamw, cosine_schedule, sgd
from repro.train import Trainer, TrainerConfig

PAPER_WB = dict(wb_rows=9, wb_cols=8)      # OU-sized blocks (paper)
BSQ_WB = dict(wb_rows=0, wb_cols=0)        # whole-layer blocks (BSQ)


def lm_quality(api, params, cfg, steps=4, seq=64, batch=16) -> float:
    """Negative CE (higher is better) on held-out synthetic batches."""
    gen = SyntheticLM(cfg.vocab, seq, batch, seed=1234)
    tot = 0.0
    for i in range(steps):
        loss, m = api.loss(params, gen.batch_at(10_000 + i))
        tot += float(m["ce"])
    return -tot / steps


def train_quantized_lm(scheme: str, steps: int = 240, alpha: float = 5e-3,
                       requant: int = 40, act_bits: int = 8,
                       arch: str = "phi3-mini-3.8b", seed: int = 0):
    """Train a tiny LM under a quantization scheme; return (api, trainer)."""
    wb = {"bwq": PAPER_WB, "bsq": BSQ_WB}.get(scheme)
    if scheme == "float":
        qc = QuantConfig(mode="none")
    else:
        qc = QuantConfig(mode="bitplane", n_bits=8, act_bits=act_bits, **wb)
    cfg = REGISTRY[arch].tiny(dtype="float32").with_quant(qc)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(seed))
    tr = Trainer(lambda p, b: api.loss(p, b), adamw(weight_decay=0.0),
                 cosine_schedule(2e-3, steps), params,
                 TrainerConfig(total_steps=steps, ckpt_every=0,
                               ckpt_dir=None, log_every=max(steps // 6, 1),
                               requant_interval=requant if qc.enabled else 0,
                               alpha_round_steps=requant if qc.enabled else 0,
                               delta_alpha=alpha if qc.enabled else 0.0),
                 alpha=0.0)
    data = make_lm_pipeline(cfg, seq_len=64, batch=16, seed=seed)
    tr.run(data, steps=steps)
    return cfg, api, tr


def train_quantized_cnn(scheme: str, model: str = "resnet20",
                        steps: int = 200, alpha: float = 5e-3,
                        requant: int = 40, act_bits: int = 8, seed: int = 0):
    """Train a small CIFAR-style CNN under a quantization scheme."""
    wb = {"bwq": PAPER_WB, "bsq": BSQ_WB}.get(scheme)
    if scheme == "float":
        qc = QuantConfig(mode="none")
    else:
        qc = QuantConfig(mode="bitplane", n_bits=8, act_bits=act_bits, **wb)
    key = jax.random.PRNGKey(seed)
    if model.startswith("resnet"):
        params = resnet_init(key, qc, depth=8)
        apply_fn = resnet_apply
    else:
        params = vgg_init(key, qc, depth=11)
        apply_fn = vgg_apply

    def loss_fn(p, b):
        return cnn_loss(apply_fn, p, b, qc)

    tr = Trainer(loss_fn, sgd(momentum=0.9, weight_decay=1e-4),
                 cosine_schedule(0.05, steps), params,
                 TrainerConfig(total_steps=steps, ckpt_every=0,
                               ckpt_dir=None, log_every=max(steps // 6, 1),
                               requant_interval=requant if qc.enabled else 0,
                               alpha_round_steps=requant if qc.enabled else 0,
                               delta_alpha=alpha if qc.enabled else 0.0))
    gen = SyntheticCIFAR(batch=64, noise=0.5, seed=seed)

    def data():
        step = 0
        while True:
            yield step, gen.batch_at(step)
            step += 1

    tr.run(data(), steps=steps)
    return qc, apply_fn, tr


def cnn_accuracy(apply_fn, params, qc, batches=4, seed=999) -> float:
    gen = SyntheticCIFAR(batch=128, noise=0.5, seed=0)
    accs = []
    for i in range(batches):
        b = gen.batch_at(50_000 + i)
        logits = apply_fn(params, b["images"], qc)
        accs.append(float(jnp.mean(
            (jnp.argmax(logits, -1) == b["labels"]).astype(jnp.float32))))
    return float(np.mean(accs))


def timed(fn, *args, n=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(n):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / n * 1e6  # us
