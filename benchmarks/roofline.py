"""Roofline report: aggregate the dry-run JSON artifacts into the
EXPERIMENTS.md §Roofline table."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.dist.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_records(tag: str = "singlepod") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"{tag}__*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_rows(tag: str = "singlepod") -> List[Dict]:
    rows = []
    for r in load_records(tag):
        t = r["roofline"]
        dom = r["dominant"]
        rows.append(dict(
            arch=r["arch"], cell=r["cell"],
            compute_s=t["compute_s"], memory_s=t["memory_s"],
            collective_s=t["collective_s"], dominant=dom,
            model_flops=r["model_flops_global"],
            hlo_flops=r["hlo_flops_global"],
            useful_frac=round(r["useful_flops_frac"], 3),
            peak_hbm_gib=r["per_device"]["peak_hbm_gib"],
            roofline_frac=round(
                t["compute_s"] / max(t["compute_s"], t["memory_s"],
                                     t["collective_s"]), 4),
        ))
    return rows


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | cell | compute_s | memory_s | collective_s | dominant "
           "| useful_frac | HBM GiB/dev | roofline_frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['arch']} | {r['cell']} | {r['compute_s']:.4g} "
                 f"| {r['memory_s']:.4g} | {r['collective_s']:.4g} "
                 f"| {r['dominant'].replace('_s','')} | {r['useful_frac']} "
                 f"| {r['peak_hbm_gib']} | {r['roofline_frac']} |\n")
    return hdr + body


def main():
    rows = roofline_rows()
    print(markdown_table(rows))
    print(f"\n{len(rows)} cells; constants: {PEAK_FLOPS/1e12:.0f} TFLOP/s, "
          f"{HBM_BW/1e9:.0f} GB/s HBM, {ICI_BW/1e9:.0f} GB/s ICI per chip")


if __name__ == "__main__":
    main()
