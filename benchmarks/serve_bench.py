"""Serving throughput benchmark: prefill + decode tokens/sec across
batch sizes, KV-cache precisions and matmul execution backends, plus a
paged-vs-fixed-width cache-residency comparison, JSON output.

``--backend {dense,pallas,ref,bitplane}`` selects how deployed weights
execute (models.common.qmatmul); ``bitplane`` deploys the plane-sliced
layout, whose ``weight_bytes_per_step`` counts true per-block plane
occupancy — the only backend whose streamed bytes vary with the BWQ-A
precision assignment.  Every row reports the per-step HBM weight-bytes
the backend streams, so the roofline column stays comparable across
backends — on CPU the wall-clock of interpret-mode pallas is NOT TPU
time, the bytes column is the transferable quantity.

Also times the OLD engine's per-step whole-tree requantization (the
pre-redesign ``_maybe_quant_cache`` behavior, reproduced inline) against
the quantized-at-rest int8 cache at the same batch — the acceptance
criterion is that at-rest decode is no slower at batch >= 8, since it
replaces O(cache) requant work per token with a one-time write-side
rounding.

The ``paged_utilization`` row drives a mixed-length request workload
through the continuous-batching scheduler twice — paged pool vs
fixed-width slots — and reports resident cache bytes (peak pages in use x
per-page footprint vs the ``n_slots * max_len`` rows a fixed layout keeps
alive) plus a parity check that both produced identical tokens.

The ``prefix_sharing`` row serves a hot shared system prompt to 16
concurrent requests twice — refcounted content-addressed prefix caching
vs the plain per-slot paged pool — reporting the prefix-hit rate,
preemption count, and the peak-resident-bytes drop from holding the
shared prompt pages exactly once (tokens must match the baseline run).

    PYTHONPATH=src python benchmarks/serve_bench.py [--quick] [--out f.json]
        [--backend pallas] [--deploy-bits 8] [--page-size 8]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY
from repro.core.pact import quantize_signed
from repro.models.api import build
from repro.models.common import QuantConfig
from repro.serve import Request, SamplingParams, ServeEngine
from repro.serve.deploy import (default_deploy_bits, default_deploy_layout,
                                to_serving_params, weight_stream_bytes)


def _sync(tree):
    jax.block_until_ready(tree)


def _bench(fn, iters: int):
    fn()                                        # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    _sync(out)
    return (time.perf_counter() - t0) / iters


def bench_point(api, params, batch_size: int, kv_bits: int,
                prompt_len: int = 32, decode_steps: int = 8,
                iters: int = 3, backend: str = "dense") -> dict:
    cfg = api.cfg
    eng = ServeEngine(api, params, kv_quant_bits=kv_bits, backend=backend)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (batch_size, prompt_len), 0,
        cfg.vocab).astype(jnp.int32)}

    logits, state = eng.prefill(batch, extra_slots=64)
    _sync(state)
    t_prefill = _bench(lambda: eng.prefill(batch, extra_slots=64), iters)

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    idx = jnp.full((batch_size,), prompt_len, jnp.int32)

    def decode_once():
        # decode donates its state argument; rebind so the next iteration
        # hands the engine a live buffer, not the donated-away one
        nonlocal state
        lg, state = eng.decode(tok, state, idx)
        return lg
    t_decode = _bench(decode_once, iters * decode_steps)

    return {
        "batch": batch_size,
        "kv_bits": kv_bits,
        "backend": backend,
        "prompt_len": prompt_len,
        "prefill_tokens_per_s": batch_size * prompt_len / t_prefill,
        "decode_tokens_per_s": batch_size / t_decode,
        "prefill_ms": t_prefill * 1e3,
        "decode_step_ms": t_decode * 1e3,
        # every decode step streams the full weight state once; this is
        # the roofline-relevant column that stays comparable across
        # backends (interpret-mode wall-clock is not TPU time)
        "weight_bytes_per_step": weight_stream_bytes(params),
    }


def bench_legacy_requant(api, params, batch_size: int,
                         prompt_len: int = 32, decode_steps: int = 8,
                         iters: int = 3, backend: str = "dense") -> dict:
    """The pre-redesign path: float cache + whole-tree re-quantization of
    every >=4-dim leaf after each decode step.  Runs on the same matmul
    backend as the at-rest rows so the speedup summary compares cache
    strategies, not backends."""
    eng = ServeEngine(api, params, kv_quant_bits=32, backend=backend)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (batch_size, prompt_len), 0,
        api.cfg.vocab).astype(jnp.int32)}
    logits, state = eng.prefill(batch, extra_slots=64)

    @jax.jit
    def requant(st):
        def q(x):
            if isinstance(x, jnp.ndarray) and x.ndim >= 4:
                return quantize_signed(x, 8)
            return x
        return jax.tree_util.tree_map(q, st)

    state = requant(state)
    _sync(state)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    idx = jnp.full((batch_size,), prompt_len, jnp.int32)

    def decode_once():
        nonlocal state
        lg, state = eng.decode(tok, state, idx)
        state = requant(state)
        return state
    t_decode = _bench(decode_once, iters * decode_steps)
    return {
        "batch": batch_size,
        "kv_bits": "legacy-requant-8",
        "backend": backend,
        "prompt_len": prompt_len,
        "decode_tokens_per_s": batch_size / t_decode,
        "decode_step_ms": t_decode * 1e3,
    }


def bench_paged_utilization(api, params, n_requests: int, kv_bits: int = 8,
                            page_size: int = 8,
                            backend: str = "dense") -> dict:
    """Mixed-length workload, paged vs fixed-width resident cache bytes."""
    cfg = api.cfg
    eng = ServeEngine(api, params, kv_quant_bits=kv_bits, backend=backend)
    p_lens = [4, 8, 16, 32]
    new_toks = [4, 16, 8, 4]
    reqs = []
    for i in range(n_requests):
        pl, mn = p_lens[i % 4], new_toks[i % 4]
        toks = jax.random.randint(jax.random.PRNGKey(100 + i), (1, pl), 0,
                                  cfg.vocab).astype(jnp.int32)
        reqs.append(Request(uid=i, inputs={"tokens": toks},
                            sampling=SamplingParams(max_new_tokens=mn),
                            arrival=i // 2))
    paged = eng.make_scheduler(reqs, n_slots=n_requests,
                               page_size=page_size)
    res_p = paged.run(list(reqs))
    rep_p = paged.cache_report()
    fixed = eng.make_scheduler(reqs, n_slots=n_requests, page_size=0)
    res_f = fixed.run(list(reqs))
    rep_f = fixed.cache_report()
    return {
        "benchmark": "paged_utilization",
        "batch": n_requests,
        "kv_bits": kv_bits,
        "page_size": page_size,
        "max_len": paged.max_len,
        "peak_pages_in_use": rep_p["peak_pages_in_use"],
        "pool_capacity_pages": rep_p["pool_capacity_pages"],
        "page_bytes": rep_p["page_bytes"],
        "paged_bytes_in_use_peak": rep_p["bytes_in_use_peak"],
        "fixed_resident_bytes": rep_f["resident_bytes"],
        "cache_utilization_vs_fixed": round(
            rep_p["bytes_in_use_peak"] / max(rep_f["resident_bytes"], 1), 4),
        "tokens_match_fixed": all(a.tokens == b.tokens
                                  for a, b in zip(res_p, res_f)),
    }


def bench_prefix_sharing(api, params, n_requests: int = 16,
                         page_size: int = 4, kv_bits: int = 8,
                         shared_tokens: int = 16, unique_tokens: int = 2,
                         max_new: int = 24, backend: str = "dense") -> dict:
    """A hot shared system prompt across ``n_requests`` concurrent
    requests: refcounted prefix caching vs the per-slot paged baseline.

    Every request carries the same ``shared_tokens``-token prefix plus a
    short unique tail; arrivals are staggered one tick apart so the first
    request's registered prompt pages are visible to every later
    admission.  With ``prefix_cache`` the pool holds the shared prefix
    pages exactly ONCE (refcounted); the baseline re-prefills and stores
    them per slot — the peak-resident-bytes gap is the headline, and both
    runs must emit identical tokens."""
    cfg = api.cfg
    shared = jax.random.randint(jax.random.PRNGKey(7), (1, shared_tokens),
                                0, cfg.vocab).astype(jnp.int32)

    def reqs():
        out = []
        for i in range(n_requests):
            tail = jax.random.randint(jax.random.PRNGKey(300 + i),
                                      (1, unique_tokens), 0,
                                      cfg.vocab).astype(jnp.int32)
            out.append(Request(
                uid=i, inputs={"tokens": jnp.concatenate([shared, tail], 1)},
                sampling=SamplingParams(max_new_tokens=max_new),
                arrival=i))
        return out

    eng = ServeEngine(api, params, kv_quant_bits=kv_bits, backend=backend)
    base = eng.make_scheduler(reqs(), n_slots=n_requests,
                              page_size=page_size, prefix_cache=False)
    res_b = base.run(reqs())
    rep_b = base.cache_report()
    cached = eng.make_scheduler(reqs(), n_slots=n_requests,
                                page_size=page_size,
                                n_pages=base.allocator.n_pages,
                                prefix_cache=True)
    res_c = cached.run(reqs())
    rep_c = cached.cache_report()
    prefix_blocks = shared_tokens // page_size
    return {
        "benchmark": "prefix_sharing",
        "batch": n_requests,
        "kv_bits": kv_bits,
        "page_size": page_size,
        "shared_prefix_tokens": shared_tokens,
        "prefix_hit_rate": round(
            rep_c["prefix_hits"] / max(rep_c["prefix_lookups"], 1), 4),
        "prefix_hits": rep_c["prefix_hits"],
        "prefix_pages_registered": rep_c["prefix_pages_registered"],
        "preemptions": rep_c["preemptions"],
        "page_bytes": rep_c["page_bytes"],
        "peak_pages_cached": rep_c["peak_pages_in_use"],
        "peak_pages_baseline": rep_b["peak_pages_in_use"],
        "cached_bytes_in_use_peak": rep_c["bytes_in_use_peak"],
        "baseline_bytes_in_use_peak": rep_b["bytes_in_use_peak"],
        "resident_bytes_vs_baseline": round(
            rep_c["bytes_in_use_peak"] / max(rep_b["bytes_in_use_peak"], 1),
            4),
        # the shared prefix is resident exactly once: the cached run's
        # peak drops by (n_requests - 1) aliased copies of its pages
        "prefix_pages_held_once": bool(
            rep_c["prefix_pages_registered"] == prefix_blocks
            and rep_b["peak_pages_in_use"] - rep_c["peak_pages_in_use"]
            >= (n_requests - 1) * prefix_blocks),
        "tokens_match_baseline": all(a.tokens == b.tokens
                                     for a, b in zip(res_c, res_b)),
    }


def bench_speculative(api, params, ks, gamma: int = 4, n_requests: int = 4,
                      max_new: int = 16, backend: str = "bitplane") -> list:
    """Self-speculative decoding: acceptance rate and drafted-vs-verified
    weight bytes per truncation depth ``k``.

    Each row drives the same greedy request workload through the
    continuous-batching scheduler with ``speculate_planes=k`` and checks
    the emitted tokens against the non-speculative engine (the greedy
    protocol is token-identical by construction, so a mismatch is a bug,
    not a quality tradeoff).  ``draft_bytes_per_step`` is what a draft
    decode step streams (top-k planes only); ``weight_bytes_per_token``
    amortizes ``drafted x draft + rounds x full`` over emitted tokens —
    below ``full_bytes_per_step`` means speculation saved weight traffic.
    """
    cfg = api.cfg

    def reqs():
        return [Request(uid=i,
                        inputs={"tokens": jax.random.randint(
                            jax.random.PRNGKey(200 + i), (1, 8 + 2 * i), 0,
                            cfg.vocab).astype(jnp.int32)},
                        sampling=SamplingParams(max_new_tokens=max_new,
                                                temperature=0.0),
                        arrival=i)
                for i in range(n_requests)]

    base = ServeEngine(api, params, backend=backend)
    sched = base.make_scheduler(reqs(), n_slots=n_requests)
    ref = {r.uid: r.tokens for r in sched.run(reqs())}
    full_bytes = weight_stream_bytes(params)

    rows = []
    for k in ks:
        eng = ServeEngine(api, params, backend=backend,
                          speculate_planes=k, draft_gamma=gamma)
        sched = eng.make_scheduler(reqs(), n_slots=n_requests)
        out = {r.uid: r.tokens for r in sched.run(reqs())}
        st = sched.spec_stats
        draft_bytes = weight_stream_bytes(eng.draft_params)
        streamed = st["drafted"] * draft_bytes + st["rounds"] * full_bytes
        rows.append({
            "benchmark": "speculative",
            "speculate_planes": k,
            "draft_gamma": gamma,
            "rounds": st["rounds"],
            "drafted": st["drafted"],
            "accepted_drafts": st["accepted_drafts"],
            "emitted": st["emitted"],
            "acceptance_rate": round(
                st["accepted_drafts"] / max(st["drafted"], 1), 4),
            "draft_bytes_per_step": draft_bytes,
            "full_bytes_per_step": full_bytes,
            "weight_bytes_per_token": round(streamed / max(st["emitted"], 1)),
            "tokens_match_baseline": out == ref,
        })
        print(json.dumps(rows[-1]), flush=True)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--quick", action="store_true",
                    help="single small point (CI smoke)")
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--backend", default="dense",
                    choices=["dense", "pallas", "ref", "bitplane"],
                    help="matmul execution backend (non-dense implies "
                         "--deploy-bits 8 unless set; bitplane deploys "
                         "the plane-sliced layout)")
    ap.add_argument("--deploy-bits", type=int, default=0, choices=[0, 4, 8],
                    help="pack weights to int8/int4 serving form first "
                         "(0 = QAT weights)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="page size for the paged-utilization row "
                         "(0 skips it)")
    ap.add_argument("--speculate", action="store_true",
                    help="add self-speculative decoding rows (acceptance "
                         "rate + drafted-vs-verified weight bytes per "
                         "truncation depth k); bitplane backend only")
    ap.add_argument("--draft-gamma", type=int, default=4,
                    help="draft tokens per speculative round")
    args = ap.parse_args()

    cfg = REGISTRY[args.arch].tiny(dtype="float32").with_quant(
        QuantConfig(mode="fake", n_bits=8, act_bits=8))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    args.deploy_bits = default_deploy_bits(args.backend, args.deploy_bits)
    if args.deploy_bits:
        params = to_serving_params(params, args.deploy_bits,
                                   layout=default_deploy_layout(args.backend))

    # the requant-vs-at-rest comparison is only meaningful once the cache
    # dominates the step (batch >= 8), so quick mode benches there too
    batches = [8] if args.quick else [2, 8, 16]
    kv_bits = [32, 8] if args.quick else [32, 8, 4]
    rows = []
    for b in batches:
        for bits in kv_bits:
            rows.append(bench_point(api, params, b, bits,
                                    backend=args.backend))
            print(json.dumps(rows[-1]), flush=True)
    # legacy comparison at the largest batch (same backend: the summary
    # isolates the cache strategy, not the matmul execution path)
    b_cmp = batches[-1]
    legacy = bench_legacy_requant(api, params, b_cmp, backend=args.backend)
    rows.append(legacy)
    print(json.dumps(legacy), flush=True)
    at_rest = next(r for r in rows
                   if r["batch"] == b_cmp and r["kv_bits"] == 8)
    speedup = legacy["decode_step_ms"] / at_rest["decode_step_ms"]
    summary = {"legacy_vs_at_rest_decode_speedup": round(speedup, 3),
               "at_rest_no_slower": bool(speedup >= 1.0),
               "compare_batch": b_cmp}
    if args.page_size:
        # residency comparison at batch 16 (8 in quick mode): the paged
        # pool only keeps pages that hold live tokens resident
        util = bench_paged_utilization(api, params,
                                       n_requests=8 if args.quick else 16,
                                       page_size=args.page_size,
                                       backend=args.backend)
        rows.append(util)
        print(json.dumps(util), flush=True)
        summary["paged_cache_utilization"] = \
            util["cache_utilization_vs_fixed"]
        summary["paged_tokens_match_fixed"] = util["tokens_match_fixed"]
        # hot shared system prompt at batch >= 16: refcounted prefix pages
        # held once vs the per-slot paged baseline
        share = bench_prefix_sharing(api, params, n_requests=16,
                                     page_size=min(args.page_size, 4),
                                     max_new=20 if args.quick else 24,
                                     backend=args.backend)
        rows.append(share)
        print(json.dumps(share), flush=True)
        summary["prefix_hit_rate"] = share["prefix_hit_rate"]
        summary["prefix_resident_bytes_vs_baseline"] = \
            share["resident_bytes_vs_baseline"]
        summary["prefix_pages_held_once"] = share["prefix_pages_held_once"]
        summary["prefix_tokens_match"] = share["tokens_match_baseline"]
    if args.speculate:
        if args.backend != "bitplane":
            raise SystemExit("--speculate requires --backend bitplane")
        bits = args.deploy_bits or 8
        ks = [bits - 2] if args.quick else [2, bits - 2, bits - 1]
        spec_rows = bench_speculative(api, params, [k for k in ks if k >= 1],
                                      gamma=args.draft_gamma,
                                      n_requests=4 if args.quick else 8,
                                      backend=args.backend)
        rows.extend(spec_rows)
        best = min(spec_rows, key=lambda r: r["weight_bytes_per_token"])
        summary["speculative_tokens_match"] = all(
            r["tokens_match_baseline"] for r in spec_rows)
        summary["speculative_best_k"] = best["speculate_planes"]
        summary["speculative_best_bytes_per_token"] = \
            best["weight_bytes_per_token"]
    result = {"rows": rows, "summary": summary,
              "note": "interpret-mode wall-clock is not TPU time; "
                      "weight_bytes_per_step is the roofline column"}
    print(json.dumps(summary), flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)


if __name__ == "__main__":
    main()
