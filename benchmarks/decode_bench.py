"""Decode microbenchmark: per-step latency across the compile-shape grid
(batch x context x page-size x kv-bits) for each decode-attention backend,
plus the fused-path footprint census.

    PYTHONPATH=src python benchmarks/decode_bench.py [--quick] [--out f.json]

Wall-clock on CPU (Pallas interpret mode for ``fused``) is NOT TPU time —
the trajectory column is ``decode_step_ms`` *relative* across backends and
shapes, and the census is the structural claim: the fused decode jaxpr
contains neither a full-width KV gather nor an f32 KV materialization
(``graph_lint`` rules ``kv-full-width-gather`` /
``kv-dequant-materialization``).  The CI smoke step runs ``--quick`` and
asserts the census is clean, so a silent fallback to the gather read side
fails fast.  Committed sweeps live in ``BENCH_decode_pr<N>.json``.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.graph_lint import lint_traced_fn
from repro.launch.lint import build_engine

ARCH = "phi3-mini-3.8b"
NOTE = ("interpret-mode wall-clock is not TPU time; "
        "bytes_per_weight is the roofline column")


def _state_for(eng, batch: int, context: int, page_size: int):
    """Zeroed decode state at fill level ``context`` (cache contents do
    not change the step's compile shape or FLOPs)."""
    example = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    max_len = context + 8
    state = eng.init_decode_state(example, batch, max_len,
                                  page_size=page_size)
    if page_size:
        nb = -(-max_len // page_size)
        tables = np.arange(1, 1 + batch * nb,
                           dtype=np.int32).reshape(batch, nb)
        state = eng.set_tables(state, tables)
    return state


def time_decode_step(eng, batch: int, context: int, page_size: int,
                     reps: int = 3) -> float:
    """Mean per-step wall-clock (ms) over ``reps`` steps after one
    compile step, re-threading the donated state like the scheduler."""
    state = _state_for(eng, batch, context, page_size)
    tok = jnp.ones((batch, 1), jnp.int32)
    index = jnp.full((batch,), context, jnp.int32)
    logits, state = eng.decode(tok, state, index)      # compile
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(reps):
        logits, state = eng.decode(tok, state, index)
    jax.block_until_ready(logits)
    return (time.perf_counter() - t0) / reps * 1e3


def decode_sweep(batches, contexts, page_sizes, kv_bits_list,
                 backends) -> List[Dict]:
    rows: List[Dict] = []
    for kv_bits in kv_bits_list:
        for ab in backends:
            eng = build_engine(ARCH, "dense", kv_bits=kv_bits,
                               attn_backend=ab)
            for b in batches:
                for ctx in contexts:
                    for page in page_sizes:
                        ms = time_decode_step(eng, b, ctx, page)
                        rows.append(dict(
                            batch=b, context=ctx, page_size=page,
                            kv_bits=kv_bits, attn_backend=ab,
                            decode_step_ms=round(ms, 3)))
                        print(f"  b={b} ctx={ctx} page={page} "
                              f"kv={kv_bits} {ab}: {ms:.2f} ms",
                              flush=True)
    return rows


def fused_decode_census(kv_bits: int = 8, page_size: int = 16,
                        batch: int = 2, context: int = 32) -> Dict:
    """Deviceless proof that the fused decode program never materializes
    the contiguous KV view or the f32 KV tree (jaxpr taint census)."""
    eng = build_engine(ARCH, "dense", kv_bits=kv_bits,
                       attn_backend="fused")
    example = {"tokens": jax.ShapeDtypeStruct((1, 8), jnp.int32)}
    state = jax.eval_shape(
        lambda p, b: eng.api.init_decode_state(
            p, b, batch, context + 8, page_size=page_size),
        eng.params, example)
    tokens = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    index = jax.ShapeDtypeStruct((batch,), jnp.int32)
    findings = lint_traced_fn(
        eng.api.decode_step, (eng.params, tokens, state, index),
        fn_name="decode", backend=eng.backend, attn_backend="fused")
    return {
        "kv_payload_rules": sorted({f.rule for f in findings
                                    if "kv" in f.rule}),
        "errors": [f.format() for f in findings if f.severity == "error"],
        "clean": all(f.severity != "error" for f in findings)
        and any(f.rule == "kv-clean" for f in findings),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny grid (CI smoke): one shape, fused+gather")
    ap.add_argument("--out", default=None, help="write JSON here")
    args = ap.parse_args()
    if args.quick:
        rows = decode_sweep(batches=(2,), contexts=(32,),
                            page_sizes=(0, 16), kv_bits_list=(8,),
                            backends=("fused", "gather"))
    else:
        rows = decode_sweep(batches=(2, 4), contexts=(32, 128),
                            page_sizes=(0, 16), kv_bits_list=(8, 4),
                            backends=("fused", "gather", "ref"))
    census = fused_decode_census()
    result = {"decode_steps": rows, "fused_decode_census": census,
              "note": NOTE}
    print(json.dumps(result, indent=2), flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)


if __name__ == "__main__":
    main()
