# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark driver: python -m benchmarks.run [--full]

Covers every paper table/figure (Table II, Figs 7-13) computed from
actually-trained quantization state, plus kernel layouts and the roofline
aggregation from the dry-run artifacts.
"""
from __future__ import annotations

import argparse
import sys
import time


def _emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer training runs for the paper tables")
    ap.add_argument("--skip-train", action="store_true",
                    help="only the fast benches (kernels, roofline)")
    args = ap.parse_args()
    quick = not args.full

    print("name,us_per_call,derived")

    from . import kernel_bench
    t0 = time.time()
    for row in kernel_bench.layout_bytes():
        _emit(f"layout/{row['layout']}", 0.0,
              f"bytes_per_weight={row['bytes_per_weight']}")
    for row in kernel_bench.kernel_timings():
        _emit(f"kernel/{row['kernel']}", row["us"], "interpret-mode")

    if not args.skip_train:
        from . import paper_tables
        t0 = time.time()
        rows = paper_tables.table2_compression(quick)
        us = (time.time() - t0) * 1e6 / max(len(rows), 1)
        for r in rows:
            _emit(f"table2/{r['model']}/{r['scheme']}", us,
                  f"quality={r['quality']};comp={r['compression_x']}x;"
                  f"avg_bits={r['avg_bitwidth']}")

        for r in paper_tables.fig9_speedup_energy():
            _emit(f"fig9/{r['model']}/{r['accel']}", 0.0,
                  f"speedup={r['speedup_x']}x;energy={r['energy_saving_x']}x")

        br = paper_tables.fig10_breakdown()
        _emit("fig10/energy_saving", 0.0, f"saving={br['saving_x']:.2f}x")
        for comp, e in br["bwq"].items():
            _emit(f"fig10/bwq/{comp}", 0.0, f"energy_j={e:.3e}")

        for r in paper_tables.fig11_indexing():
            _emit(f"fig11/{r['model']}/{r['accel']}", 0.0,
                  f"index_KB={r['index_KB']}")

        for r in paper_tables.fig12_ablation(quick):
            _emit(f"fig12/a{r['alpha']}/i{r['requant_interval']}", 0.0,
                  f"quality={r['quality']};comp={r['compression_x']}x")

        for r in paper_tables.fig13_ou_size():
            _emit(f"fig13/ou{r['ou']}", 0.0,
                  f"avg_bits={r['avg_bits']};runtime_s={r['runtime_s']:.3e};"
                  f"energy_j={r['energy_j']:.3e}")

        for name, mean_bits in paper_tables.fig7_bitmaps().items():
            _emit(f"fig7/{name}", 0.0, f"mean_bits={mean_bits:.2f}")

    # roofline (requires dry-run artifacts; skip silently if absent)
    try:
        from . import roofline
        rows = roofline.roofline_rows()
        for r in rows:
            _emit(f"roofline/{r['arch']}/{r['cell']}", 0.0,
                  f"dominant={r['dominant']};useful_frac={r['useful_frac']};"
                  f"hbm_gib={r['peak_hbm_gib']}")
    except Exception as e:  # pragma: no cover
        print(f"# roofline skipped: {e}", file=sys.stderr)


if __name__ == "__main__":
    main()
