"""Paper-table benchmarks (Table II, Figs 7-13) computed from trained
quantization state + the BWQ-H analytical simulator."""
from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np

from repro.core import (BlockingSpec, adjust_precision, bitwidths, compose,
                        from_float, requantize)
from repro.core.state import per_layer_bitwidth_maps, quantized_leaves
from repro.hw import (PAPER_SPEC, bsq_scheme, bwq_scheme, isaac_scheme,
                      simulate, sme_scheme, speedup_and_energy_saving,
                      sre_scheme, workloads_from_params)
from repro.train.step import quant_stats

from .common import (cnn_accuracy, lm_quality, train_quantized_cnn,
                     train_quantized_lm)

OUT_DIR = os.environ.get("BENCH_OUT", "experiments/bench")


def _save(name: str, obj) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, name), "w") as f:
        json.dump(obj, f, indent=1)


# ---------------------------------------------------------------------------
# Table II — accuracy vs compression, BWQ-A vs BSQ vs float
# ---------------------------------------------------------------------------

def table2_compression(quick: bool = True) -> List[Dict]:
    steps = 120 if quick else 480
    rows = []
    for model, kind in [("tiny-lm(phi3)", "lm"), ("resnet8-cifar", "cnn")]:
        per_scheme = {}
        for scheme in ("float", "bsq", "bwq"):
            if kind == "lm":
                cfg, api, tr = train_quantized_lm(scheme, steps=steps)
                quality = lm_quality(api, tr.state.params, cfg)
                stats = {k: float(v) for k, v in
                         quant_stats(tr.state.params).items()}
            else:
                qc, apply_fn, tr = train_quantized_cnn(scheme, steps=steps)
                quality = cnn_accuracy(apply_fn, tr.state.params, qc)
                stats = {k: float(v) for k, v in
                         quant_stats(tr.state.params).items()}
            per_scheme[scheme] = dict(quality=quality, **stats,
                                      params=tr.state.params)
        for scheme in ("float", "bsq", "bwq"):
            r = per_scheme[scheme]
            rows.append(dict(model=model, scheme=scheme,
                             quality=round(r["quality"], 4),
                             avg_bitwidth=round(r["avg_bitwidth"], 3),
                             compression_x=round(r["compression_x"], 2)))
        table2_compression.trained = getattr(table2_compression, "trained", {})
        table2_compression.trained[model] = per_scheme
    _save("table2_compression.json", rows)
    return rows


# ---------------------------------------------------------------------------
# Fig 9/10/11 — accelerator speedup, energy breakdown, indexing overhead
# ---------------------------------------------------------------------------

def fig9_speedup_energy(trained=None, quick: bool = True) -> List[Dict]:
    if trained is None:
        trained = getattr(table2_compression, "trained", None)
    if trained is None:
        table2_compression(quick)
        trained = table2_compression.trained
    rows = []
    for model, per_scheme in trained.items():
        # hardware workloads from the *trained BWQ state* (positions ~ conv
        # output pixels / LM tokens)
        wls = workloads_from_params(per_scheme["bwq"]["params"],
                                    positions=64, act_bits=3)
        base = isaac_scheme()
        bwq_sp, bwq_en = speedup_and_energy_saving(wls, bwq_scheme(), base)
        # BSQ executes layer-uniform precision at OU granularity: evaluate
        # its learned average bit-width as a uniform scheme over the same
        # OU-sized workload grid (whole-layer WB tables would wrongly give
        # the hardware mapper one giant block per layer).
        bsq_bits = max(1, round(per_scheme["bsq"]["avg_bitwidth"]))
        bsq_sp, bsq_en = speedup_and_energy_saving(
            wls, bsq_scheme(bsq_bits), base)
        for name, sp, en in [("BWQ-H", bwq_sp, bwq_en),
                             ("BSQ", bsq_sp, bsq_en)]:
            rows.append(dict(model=model, accel=name,
                             speedup_x=round(sp, 2),
                             energy_saving_x=round(en, 2)))
        for sch in (sre_scheme(), sme_scheme()):
            sp, en = speedup_and_energy_saving(wls, sch, base)
            rows.append(dict(model=model, accel=sch.name,
                             speedup_x=round(sp, 2),
                             energy_saving_x=round(en, 2)))
        rows.append(dict(model=model, accel="ISAAC", speedup_x=1.0,
                         energy_saving_x=1.0))
    _save("fig9_speedup_energy.json", rows)
    return rows


def fig10_breakdown(trained=None) -> Dict:
    if trained is None:
        trained = table2_compression.trained
    model, per_scheme = next(iter(trained.items()))
    wls = workloads_from_params(per_scheme["bwq"]["params"], positions=64,
                                act_bits=3)
    rep_bwq = simulate(wls, bwq_scheme())
    rep_isaac = simulate(wls, isaac_scheme())
    out = dict(model=model,
               bwq=rep_bwq.energy_breakdown(),
               isaac=rep_isaac.energy_breakdown(),
               saving_x=rep_isaac.energy_j / rep_bwq.energy_j)
    _save("fig10_breakdown.json", out)
    return out


def fig11_indexing(trained=None) -> List[Dict]:
    if trained is None:
        trained = table2_compression.trained
    rows = []
    for model, per_scheme in trained.items():
        wls = workloads_from_params(per_scheme["bwq"]["params"],
                                    positions=64, act_bits=3)
        for sch in (bwq_scheme(), sre_scheme(), sme_scheme(),
                    bsq_scheme(4)):
            rep = simulate(wls, sch)
            rows.append(dict(model=model, accel=sch.name,
                             index_KB=round(rep.index_bits / 8 / 1024, 2)))
    _save("fig11_indexing.json", rows)
    return rows


# ---------------------------------------------------------------------------
# Fig 12 — regularization strength x re-quantization interval ablation
# ---------------------------------------------------------------------------

def fig12_ablation(quick: bool = True) -> List[Dict]:
    steps = 80 if quick else 360
    rows = []
    alphas = [5e-4, 5e-3] if quick else [5e-4, 1e-3, 3e-3, 5e-3, 1e-2]
    intervals = [20, 60] if quick else [20, 40, 80]
    for alpha in alphas:
        for interval in intervals:
            cfg, api, tr = train_quantized_lm("bwq", steps=steps,
                                              alpha=alpha, requant=interval)
            q = lm_quality(api, tr.state.params, cfg)
            st = quant_stats(tr.state.params)
            rows.append(dict(alpha=alpha, requant_interval=interval,
                             quality=round(q, 4),
                             compression_x=round(float(
                                 st["compression_x"]), 2)))
    _save("fig12_ablation.json", rows)
    return rows


# ---------------------------------------------------------------------------
# Fig 13 — OU-size scalability (re-block the trained tensors)
# ---------------------------------------------------------------------------

def fig13_ou_size(trained=None) -> List[Dict]:
    if trained is None:
        trained = table2_compression.trained
    model, per_scheme = next(iter(trained.items()))
    params = per_scheme["bwq"]["params"]
    qts = quantized_leaves(params)
    rows = []
    for rows_, cols in [(9, 8), (16, 16), (32, 32), (64, 64), (128, 128)]:
        spec = PAPER_SPEC.with_ou(rows_, cols)
        total_bits = total_params = 0.0
        wls = []
        from repro.hw.simulator import LayerWorkload
        from repro.core.blocking import block_elem_counts
        for name, qt in qts.items():
            w = compose(qt)
            if w.ndim > 2:
                w = w.reshape(-1, w.shape[-1])
            qt2 = adjust_precision(requantize(from_float(
                w, 8, BlockingSpec(rows_, cols))))
            bw = np.asarray(bitwidths(qt2))
            elems = np.asarray(block_elem_counts(w.shape,
                                                 qt2.spec))
            total_bits += float((bw * elems).sum())
            total_params += w.size
            wls.append(LayerWorkload(name, w.shape[0], w.shape[1],
                                     positions=64, bitwidths=bw, act_bits=3))
        rep = simulate(wls, bwq_scheme(), spec)
        rows.append(dict(ou=f"{rows_}x{cols}",
                         avg_bits=round(total_bits / total_params, 3),
                         model_size_rel=round(total_bits / (8 * total_params),
                                              4),
                         runtime_s=rep.latency_s,
                         energy_j=rep.energy_j,
                         adc_energy_j=rep.energy_breakdown()["adc"]))
    _save("fig13_ou_size.json", rows)
    return rows


def fig7_bitmaps(trained=None) -> Dict:
    """Per-layer WB bit-width heatmaps (saved as nested lists)."""
    if trained is None:
        trained = table2_compression.trained
    model, per_scheme = next(iter(trained.items()))
    maps = per_layer_bitwidth_maps(per_scheme["bwq"]["params"])
    out = {k: np.asarray(v)[..., :16, :16].tolist()  # clip for readability
           for k, v in list(maps.items())[:4]}
    _save("fig7_bitmaps.json", out)
    return {k: np.mean(v) for k, v in out.items()}
