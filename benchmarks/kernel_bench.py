"""Kernel-level benchmark: HBM weight-bytes per layout + interpret-mode
correctness timing.  Wall-clock on CPU interpret mode is NOT TPU time; the
derived column (bytes/weight) is the roofline-relevant quantity.

    PYTHONPATH=src python benchmarks/kernel_bench.py [--quick] [--out f.json]

emits one JSON object (layout bytes + kernel timings) — the CI smoke step
runs ``--quick`` so a kernel-backend regression fails fast.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax

from repro.core import BlockingSpec, adjust_precision, from_float, requantize
from repro.kernels import (bwq_dense_bitplane, bwq_dense_packed,
                           to_bitplane_layout, to_packed_layout)
from repro.serve.deploy import to_serving_params, weight_stream_bytes


def _mixed_qt(k: int, n: int, pruned_frac: float = 0.5, seed: int = 0):
    """A QuantizedTensor with a genuinely mixed precision assignment."""
    import dataclasses
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * 0.05
    qt = requantize(from_float(w, 8, BlockingSpec(8, 128)))
    cut = int(n * pruned_frac) // 128 * 128
    planes = qt.planes.at[4:, :, :cut].set(0.0)
    return requantize(adjust_precision(dataclasses.replace(qt,
                                                           planes=planes)))


def layout_bytes(k: int = 1024, n: int = 1024, pruned_frac: float = 0.5
                 ) -> List[Dict]:
    """Weight bytes streamed from HBM per matmul for each storage layout."""
    qt = _mixed_qt(k, n, pruned_frac)

    bl = to_bitplane_layout(qt)
    pk8 = to_packed_layout(qt, 8)
    pk4 = to_packed_layout(qt, 4)
    # serving wire formats (what ServeEngine actually streams per step)
    bp8 = to_serving_params({"w": qt}, 8, layout="bitplane")
    bp4 = to_serving_params({"w": qt}, 4, layout="bitplane")
    rows = [
        dict(layout="bf16 dense", bytes_per_weight=2.0),
        dict(layout="f32 dense", bytes_per_weight=4.0),
        dict(layout="bwq bitplane(packed)+sign",
             bytes_per_weight=round(
                 (bl.planes_packed.size + bl.sign_packed.size
                  + bl.mask.size * 4) / (k * n), 4)),
        dict(layout="bwq int8 + per-WB scale",
             bytes_per_weight=round(
                 (pk8.w_int.size + pk8.scale.size * 4) / (k * n), 4)),
        dict(layout="bwq int4 + per-WB scale",
             bytes_per_weight=round(
                 (pk4.w_int.size + pk4.scale.size * 4) / (k * n), 4)),
        # per-block plane occupancy: only live (bit, block) planes stream,
        # so bytes track the precision assignment (backend="bitplane")
        dict(layout="bwq bitplane serving int8 (plane occupancy)",
             bytes_per_weight=round(weight_stream_bytes(bp8) / (k * n), 4)),
        dict(layout="bwq bitplane serving int4 (plane occupancy)",
             bytes_per_weight=round(weight_stream_bytes(bp4) / (k * n), 4)),
    ]
    return rows


def _t(f, *a):
    f(*a)  # compile
    t0 = time.perf_counter()
    for _ in range(3):
        r = f(*a)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / 3 * 1e6


def kernel_timings(m: int = 64, k: int = 512, n: int = 512) -> List[Dict]:
    from repro.models.common import qmatmul
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.05
    qt = requantize(from_float(w, 8, BlockingSpec(8, 128)))
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    bl = to_bitplane_layout(qt)
    pk8 = to_packed_layout(qt, 8)
    pk4 = to_packed_layout(qt, 4)
    bp8 = to_serving_params({"w": _mixed_qt(k, n)}, 8,
                            layout="bitplane")["w"]

    t = _t
    return [
        dict(kernel="bitplane_matmul(interp)", us=round(t(
            lambda: bwq_dense_bitplane(x, bl)), 1)),
        dict(kernel="bitplane_serving_matmul(interp)", us=round(t(
            lambda: qmatmul(x, bp8, backend="bitplane")), 1)),
        dict(kernel="packed_matmul8(interp)", us=round(t(
            lambda: bwq_dense_packed(x, pk8)), 1)),
        dict(kernel="packed_matmul4(interp)", us=round(t(
            lambda: bwq_dense_packed(x, pk4)), 1)),
        dict(kernel="jnp_dense_ref", us=round(t(
            lambda: jax.jit(lambda: x @ w)()), 1)),
    ]


def paged_attention_timings(b: int = 4, kv: int = 4, g: int = 2,
                            dh: int = 64, page: int = 16,
                            nb: int = 8) -> List[Dict]:
    """Decode attention over an int8 page pool: fused kernel (interpret)
    vs the gather composite it replaces vs the jnp oracle."""
    import jax.numpy as jnp

    from repro.kernels import paged_attention
    from repro.kernels.ref import paged_attention_ref
    from repro.models.attention import (attention_core, dequantize_kv,
                                        paged_gather)

    n_pages = 1 + b * nb
    t_len = nb * page
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, kv, g, dh), jnp.float32)
    kp = jax.random.randint(ks[1], (n_pages, page, kv, dh),
                            -127, 128).astype(jnp.int8)
    vp = jax.random.randint(ks[2], (n_pages, page, kv, dh),
                            -127, 128).astype(jnp.int8)
    ksc = jax.random.uniform(ks[3], (n_pages, page, kv), jnp.float32,
                             0.005, 0.02)
    vsc = jax.random.uniform(ks[4], (n_pages, page, kv), jnp.float32,
                             0.005, 0.02)
    table = jax.numpy.arange(1, 1 + b * nb,
                             dtype=jnp.int32).reshape(b, nb)
    kv_len = jnp.full((b,), t_len, jnp.int32)

    @jax.jit
    def gather_composite():
        k = dequantize_kv(paged_gather(kp, table),
                          paged_gather(ksc, table), jnp.float32)
        v = dequantize_kv(paged_gather(vp, table),
                          paged_gather(vsc, table), jnp.float32)
        q_core = q.reshape(b, 1, kv * g, dh)
        q_pos = jnp.full((b, 1), t_len - 1, jnp.int32)
        kv_pos = jnp.broadcast_to(jnp.arange(t_len)[None, :], (b, t_len))
        return attention_core(q_core, k, v, q_pos, kv_pos,
                              kv_len=kv_len)

    return [
        dict(kernel="paged_attention_fused(interp)", us=round(_t(
            lambda: paged_attention(q, kp, vp, ksc, vsc, table,
                                    kv_len)), 1)),
        dict(kernel="paged_attention_gather", us=round(_t(
            gather_composite), 1)),
        dict(kernel="paged_attention_ref", us=round(_t(
            jax.jit(lambda: paged_attention_ref(q, kp, vp, ksc, vsc,
                                                table, kv_len))), 1)),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes (CI smoke)")
    ap.add_argument("--out", default=None, help="write JSON here")
    args = ap.parse_args()
    if args.quick:
        layouts = layout_bytes(k=256, n=256)
        timings = kernel_timings(m=16, k=256, n=256)
        timings += paged_attention_timings(b=2, kv=2, g=2, dh=32,
                                           page=8, nb=4)
    else:
        layouts = layout_bytes()
        timings = kernel_timings()
        timings += paged_attention_timings()
    result = {"layout_bytes": layouts, "kernel_timings": timings,
              "note": "interpret-mode wall-clock is not TPU time; "
                      "bytes_per_weight is the roofline column"}
    print(json.dumps(result, indent=2), flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)


if __name__ == "__main__":
    main()
