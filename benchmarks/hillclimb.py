"""§Perf hillclimb driver: lower named variants of the three chosen cells
and record hypothesis -> change -> before/after roofline terms.

Run:  PYTHONPATH=src python -m benchmarks.hillclimb --cell <name>
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json


from repro.configs import REGISTRY
from repro.configs.base import ShapeCell
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh

OUT = "experiments/perf"

CELLS = {
    "phi3_decode": ("phi3-mini-3.8b", ShapeCell("decode_32k", 32768, 128,
                                                "decode")),
    "llama4_decode": ("llama4-scout-17b-a16e",
                      ShapeCell("decode_32k", 32768, 128, "decode")),
    "gemma2_train": ("gemma2-27b", ShapeCell("train_4k", 4096, 256,
                                             "train")),
}

# kernels/paged_attention.py tile candidates (KV heads per grid cell) —
# the genuine autotuning knob the fused decode kernel exposes.  On CPU
# the sweep times interpret mode (relative only); rerun on a real TPU
# to pick the deployed default.
PAGED_ATTN_TILES = [dict(block_kv=1), dict(block_kv=2), dict(block_kv=4)]


def run_paged_attn_variant(tag: str, block_kv: int, b: int = 8,
                           kv: int = 8, g: int = 4, dh: int = 128,
                           page: int = 64, nb: int = 8):
    """Time the fused decode kernel at one ``block_kv`` tile setting."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels import paged_attention

    n_pages = 1 + b * nb
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    q = jax.random.normal(ks[0], (b, kv, g, dh), jnp.float32)
    kp = jax.random.randint(ks[1], (n_pages, page, kv, dh),
                            -127, 128).astype(jnp.int8)
    vp = jax.random.randint(ks[2], (n_pages, page, kv, dh),
                            -127, 128).astype(jnp.int8)
    ksc = jax.random.uniform(ks[3], (n_pages, page, kv), jnp.float32,
                             0.005, 0.02)
    vsc = jax.random.uniform(ks[4], (n_pages, page, kv), jnp.float32,
                             0.005, 0.02)
    table = jnp.arange(1, 1 + b * nb, dtype=jnp.int32).reshape(b, nb)
    kv_len = jnp.full((b,), nb * page, jnp.int32)

    def step():
        return paged_attention(q, kp, vp, ksc, vsc, table, kv_len,
                               block_kv=block_kv)

    jax.block_until_ready(step())            # compile
    t0 = time.perf_counter()
    for _ in range(3):
        r = step()
    jax.block_until_ready(r)
    us = (time.perf_counter() - t0) / 3 * 1e6
    rec = dict(variant=tag, block_kv=block_kv, us=round(us, 1),
               shape=dict(b=b, kv=kv, g=g, dh=dh, page=page, nb=nb),
               backend=jax.default_backend())
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, f"paged_attn__{tag}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[paged_attn/{tag}] block_kv={block_kv} {us:.1f} us "
          f"({jax.default_backend()})", flush=True)
    return rec


def run_variant(cell_key: str, tag: str, cfg_over=None, fsdp=True, **kw):
    from repro.dist import sharding as sh
    arch, cell = CELLS[cell_key]
    cfg = REGISTRY[arch]
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    mesh = make_production_mesh()
    old = sh.FSDP["enabled"]
    sh.FSDP["enabled"] = fsdp
    try:
        rec = lower_cell(cfg, cell, mesh, **kw)
    finally:
        sh.FSDP["enabled"] = old
    rec["variant"] = tag
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, f"{cell_key}__{tag}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    t = rec["roofline"]
    print(f"[{cell_key}/{tag}] compute={t['compute_s']:.4g} "
          f"memory={t['memory_s']:.4g} coll={t['collective_s']:.4g} "
          f"dominant={rec['dominant']} hbm={rec['per_device']['peak_hbm_gib']}"
          f"GiB useful={rec['useful_flops_frac']:.3f}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    choices=list(CELLS) + ["paged_attn", "all"])
    ap.add_argument("--variant", default="all")
    args = ap.parse_args()

    if args.cell == "paged_attn":
        for cand in PAGED_ATTN_TILES:
            tag = f"block_kv{cand['block_kv']}"
            if args.variant not in ("all", tag):
                continue
            run_paged_attn_variant(tag, **cand)
        return

    plans = {
        # (tag, cfg overrides, fsdp, lower_cell kwargs)
        "phi3_decode": [
            ("baseline_f32", None, True, {}),
            ("int8_weights", None, True, dict(deploy_bits=8)),
            ("kv8_cache", dict(kv_cache_bits=8), True, {}),
            ("kv8_int8_resident", dict(kv_cache_bits=8), False,
             dict(deploy_bits=8)),
            ("kv8_int4_resident", dict(kv_cache_bits=8), False,
             dict(deploy_bits=4)),
        ],
        "llama4_decode": [
            ("baseline_f32", None, True, {}),
            ("int8_weights", None, True, dict(deploy_bits=8)),
            ("int8_resident", None, False, dict(deploy_bits=8)),
            ("int4_resident", None, False, dict(deploy_bits=4)),
            ("kv8_int4_resident", dict(kv_cache_bits=8), False,
             dict(deploy_bits=4)),
            ("kv8_int8_resident", dict(kv_cache_bits=8), False,
             dict(deploy_bits=8)),
        ],
        "gemma2_train": [
            ("baseline_mb16", None, True, {}),
            ("mb8", None, True, dict(microbatches=8)),
            ("mb4", None, True, dict(microbatches=4)),
            ("mb8_noremat", dict(remat=False), True, dict(microbatches=8)),
        ],
    }
    cells = list(CELLS) if args.cell == "all" else [args.cell]
    for c in cells:
        for tag, over, fsdp, kw in plans[c]:
            if args.variant not in ("all", tag):
                continue
            try:
                run_variant(c, tag, over, fsdp=fsdp, **kw)
            except Exception as e:
                print(f"[{c}/{tag}] FAIL {type(e).__name__}: {e}",
                      flush=True)


if __name__ == "__main__":
    main()
